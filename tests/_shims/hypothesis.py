"""Minimal stand-in for the ``hypothesis`` package (used only when the real
library is not installed — see conftest.py).

Implements the tiny surface the test-suite uses: ``@given`` over
``integers`` / ``floats`` / ``binary`` / ``sampled_from`` strategies and a
``@settings(max_examples=..., deadline=...)`` decorator.  Examples are drawn
deterministically (fixed seed sequence); example 0 pins every strategy to its
minimum and example 1 to its maximum so boundary cases are always exercised.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Sequence

import numpy as np

DEFAULT_MAX_EXAMPLES = 20
_SEED = 0x48595053  # 'HYPS'


class SearchStrategy:
    def example(self, rng: np.random.Generator, mode: str) -> Any:
        raise NotImplementedError


class _Integers(SearchStrategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)

    def example(self, rng, mode):
        if mode == "min":
            return self.lo
        if mode == "max":
            return self.hi
        return int(rng.integers(self.lo, self.hi + 1))


class _Floats(SearchStrategy):
    def __init__(self, lo: float, hi: float):
        self.lo, self.hi = float(lo), float(hi)

    def example(self, rng, mode):
        if mode == "min":
            return self.lo
        if mode == "max":
            return self.hi
        return float(rng.uniform(self.lo, self.hi))


class _Binary(SearchStrategy):
    def __init__(self, min_size: int = 0, max_size: int = 2 ** 16):
        self.min_size, self.max_size = int(min_size), int(max_size)

    def example(self, rng, mode):
        if mode == "min":
            n = self.min_size
        elif mode == "max":
            n = self.max_size
        else:
            n = int(rng.integers(self.min_size, self.max_size + 1))
        return rng.integers(0, 256, n, dtype=np.uint8).tobytes()


class _SampledFrom(SearchStrategy):
    def __init__(self, elements: Sequence[Any]):
        self.elements = list(elements)

    def example(self, rng, mode):
        if mode == "min":
            return self.elements[0]
        if mode == "max":
            return self.elements[-1]
        return self.elements[int(rng.integers(len(self.elements)))]


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
        return _Floats(min_value, max_value)

    @staticmethod
    def binary(min_size: int = 0, max_size: int = 2 ** 16) -> SearchStrategy:
        return _Binary(min_size, max_size)

    @staticmethod
    def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
        return _SampledFrom(elements)


def given(*strats: SearchStrategy) -> Callable:
    def deco(fn: Callable) -> Callable:
        # NB: no functools.wraps — the wrapper must present a ZERO-arg
        # signature or pytest would try to resolve the drawn args as fixtures.
        def wrapper():
            n = getattr(wrapper, "_hyp_max_examples", DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                mode = "min" if i == 0 else ("max" if i == 1 else "rand")
                rng = np.random.default_rng(_SEED + i)
                drawn = tuple(s.example(rng, mode) for s in strats)
                try:
                    fn(*drawn)
                except Exception as exc:  # noqa: BLE001 - re-raise w/ context
                    raise AssertionError(
                        f"falsifying example (#{i}): {drawn!r}") from exc
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._hyp_max_examples = getattr(fn, "_hyp_max_examples",
                                            DEFAULT_MAX_EXAMPLES)
        wrapper._hyp_given = True
        return wrapper
    return deco


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn: Callable) -> Callable:
        fn._hyp_max_examples = max_examples
        return fn
    return deco
