"""Checkpointing (exact/partial/async), crash-restart, straggler detection,
gradient compression convergence, trainer loss decrease."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import bitcast_codec as bc
from repro.ckpt import manager as ck
from repro.configs.base import ModelConfig
from repro.distributed.grad_compress import ef_quantize
from repro.models.model import Model
from repro.optim import adamw
from repro.train.loop import Trainer, TrainerConfig, synthetic_data

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                   compute_dtype="float32", remat=False)


# ------------------------------------------------------------------- codec --

@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_codec_bit_exact_full(dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=3000).astype(np.float32) * 100,
                    jnp.dtype(dtype))
    xn = np.asarray(x)
    r = bc.exact_refactor(xn)
    blob = bc.exact_to_bytes(r)
    r2 = bc.exact_from_bytes(blob)
    full, _ = bc.exact_retrieve(r2, None)
    assert np.array_equal(full.view(np.uint8), xn.view(np.uint8))


def test_codec_progressive_relative_error():
    rng = np.random.default_rng(1)
    x = (rng.normal(size=5000)
         * np.exp2(rng.integers(-10, 10, 5000))).astype(np.float32)
    r = bc.exact_refactor(x)
    prev_bytes = 0
    for rel in [1e-1, 1e-2, 1e-4, None]:
        out, nb = bc.exact_retrieve(r, rel)
        if rel is not None:
            err = np.abs(out.astype(np.float64) - x.astype(np.float64))
            relerr = err / np.maximum(np.abs(x.astype(np.float64)), 1e-30)
            assert relerr.max() <= rel * 1.01 + 2 ** -23, rel
        assert nb >= prev_bytes    # monotone cost in precision
        prev_bytes = nb
    assert np.array_equal(out, x)


def test_ckpt_save_load_partial(tmp_path):
    tree = {"w": jnp.asarray(np.random.default_rng(2).normal(
        size=(128, 64)).astype(np.float32)),
        "step": jnp.int32(3)}
    ck.save(tmp_path, 3, tree)
    exact, stats = ck.load(tmp_path, 3, tree)
    assert np.array_equal(np.asarray(exact["w"]), np.asarray(tree["w"]))
    approx, stats2 = ck.load(tmp_path, 3, tree, rel_error=1e-2)
    assert stats2["read_fraction"] < 0.75
    rel = np.abs(np.asarray(approx["w"]) - np.asarray(tree["w"])) / \
        np.maximum(np.abs(np.asarray(tree["w"])), 1e-30)
    assert rel.max() <= 1e-2 + 2 ** -8


def test_async_checkpointer(tmp_path):
    a = ck.AsyncCheckpointer(tmp_path)
    tree = {"w": jnp.ones((2048,), jnp.float32)}
    a.save(5, tree)
    a.wait()
    assert ck.latest_step(tmp_path) == 5


def test_save_sweeps_stale_tmp_dirs(tmp_path):
    """A crashed save leaves .tmp_step_M behind; the NEXT save (any step)
    must clean it up instead of leaking a checkpoint of disk per crash."""
    tree = {"w": jnp.ones((64,), jnp.float32)}
    stale = tmp_path / ".tmp_step_00000007"
    stale.mkdir(parents=True)
    (stale / "w.raw").write_bytes(b"\0" * 256)  # half-written leftovers
    ck.save(tmp_path, 9, tree)
    assert not stale.exists()
    assert ck.latest_step(tmp_path) == 9
    loaded, _ = ck.load(tmp_path, 9, tree)
    assert np.array_equal(np.asarray(loaded["w"]), np.asarray(tree["w"]))


def test_mid_save_crash_leaves_previous_checkpoint_loadable(tmp_path, monkeypatch):
    """Atomicity under a crash DURING save: the interrupted step never
    becomes latest, the previous checkpoint still loads bit-exactly, and the
    recovery save cleans the wreckage."""
    rng = np.random.default_rng(4)
    tree1 = {"w": jnp.asarray(rng.normal(size=(2048,)).astype(np.float32))}
    tree2 = {"w": jnp.asarray(rng.normal(size=(2048,)).astype(np.float32))}
    ck.save(tmp_path, 1, tree1)

    calls = {"n": 0}
    real_write_bytes = ck.Path.write_bytes

    def crashing_write_bytes(self, data):
        calls["n"] += 1
        if calls["n"] == 1:
            # simulate dying mid-write: leave a partial file, then raise
            real_write_bytes(self, data[: len(data) // 2])
            raise RuntimeError("simulated crash mid-save")
        return real_write_bytes(self, data)

    monkeypatch.setattr(ck.Path, "write_bytes", crashing_write_bytes)
    with pytest.raises(RuntimeError):
        ck.save(tmp_path, 2, tree2)
    monkeypatch.setattr(ck.Path, "write_bytes", real_write_bytes)
    # the torn step is invisible (no manifest => not a checkpoint) and the
    # previous one is intact
    assert ck.latest_step(tmp_path) == 1
    loaded, _ = ck.load(tmp_path, 1, tree1)
    assert np.array_equal(np.asarray(loaded["w"]), np.asarray(tree1["w"]))
    # wreckage exists now, and the next successful save sweeps it
    assert (tmp_path / ".tmp_step_00000002").exists()
    ck.save(tmp_path, 3, tree2)
    assert not (tmp_path / ".tmp_step_00000002").exists()
    assert ck.latest_step(tmp_path) == 3
    loaded3, _ = ck.load(tmp_path, 3, tree2)
    assert np.array_equal(np.asarray(loaded3["w"]), np.asarray(tree2["w"]))


# ----------------------------------------------------------------- trainer --

def _mk_trainer(tmp_path, total=30, crash=None, planes=0, straggle=False):
    m = Model(TINY)
    data = synthetic_data(TINY, batch=4, seq=16, seed=1)
    if straggle:
        base = data

        def data(step, _base=base):
            if step == 20:
                time.sleep(1.0)  # injected host-side straggle
            return _base(step)
    t = Trainer(m, adamw.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=total),
                TrainerConfig(total_steps=total, ckpt_every=10,
                              ckpt_dir=str(tmp_path), log_every=5,
                              grad_compress_planes=planes), data)
    return t


def test_trainer_loss_decreases(tmp_path):
    res = _mk_trainer(tmp_path / "a", total=40).run()
    losses = [m["loss"] for m in res["metrics"]]
    assert losses[-1] < losses[0]


def test_crash_restart_resumes_exactly(tmp_path):
    d = tmp_path / "b"
    with pytest.raises(RuntimeError, match="injected crash"):
        _mk_trainer(d, total=30, crash=None).run(crash_at=20)
    # fresh trainer resumes from step 20 checkpoint and finishes
    res = _mk_trainer(d, total=30).run()
    assert res["final_step"] == 30
    # determinism: a never-crashed run gives identical params
    res2 = _mk_trainer(tmp_path / "c", total=30).run()
    for a, b in zip(jax.tree.leaves(res["params"]),
                    jax.tree.leaves(res2["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_straggler_detection(tmp_path):
    t = _mk_trainer(tmp_path / "d", total=30, straggle=True)
    res = t.run()
    assert res["straggler_events"] >= 1


def test_grad_compression_converges(tmp_path):
    base = _mk_trainer(tmp_path / "e", total=40).run()
    comp = _mk_trainer(tmp_path / "f", total=40, planes=8).run()
    lb = base["metrics"][-1]["loss"]
    lc = comp["metrics"][-1]["loss"]
    assert lc < base["metrics"][0]["loss"]           # it learns
    assert abs(lc - lb) / lb < 0.25                  # and tracks the baseline


def test_ef_quantize_unbiased_accumulation():
    rng = np.random.default_rng(5)
    g = jnp.asarray(rng.normal(size=4096).astype(np.float32))
    res = jnp.zeros_like(g)
    total_q = jnp.zeros_like(g)
    for _ in range(8):
        q, res = ef_quantize(g, res, planes=4)
        total_q = total_q + q
    # error feedback: accumulated quantized grads track accumulated true grads
    err = float(jnp.abs(total_q - 8 * g).max()) / float(jnp.abs(g).max())
    assert err < 0.15
