"""End-to-end behaviour of the paper's system: refactor -> progressive
retrieve with guaranteed error control, incrementality, and QoI control."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import refactor as rf
from repro.core import retrieve as rt
from repro.core import qoi as qq
from repro.data.fields import gaussian_field, velocity_field


@pytest.fixture(scope="module")
def field():
    return gaussian_field((40, 40, 40), slope=-2.2, seed=11)


@pytest.fixture(scope="module")
def refd(field):
    return rf.refactor_array(field, "v")


def test_progressive_guarantee(field, refd):
    reader = rt.ProgressiveReader(refd)
    prev_err = np.inf
    for tol in [1e-1, 1e-2, 1e-3, 1e-4, 1e-5]:
        xh, bound, _ = reader.retrieve(tol)
        actual = float(np.abs(xh - field).max())
        assert actual <= bound, (tol, actual, bound)
        assert bound <= max(tol, reader.floor_bound() * 1.001)
        assert actual <= prev_err * (1 + 1e-9)   # monotone improvement
        prev_err = actual


def test_incremental_fetches_are_deltas(field, refd):
    r1 = rt.ProgressiveReader(refd)
    r1.retrieve(1e-2)
    b1 = r1.total_bytes_fetched
    r1.retrieve(1e-4)
    b2 = r1.total_bytes_fetched
    fresh = rt.ProgressiveReader(refd)
    fresh.retrieve(1e-4)
    # going straight to 1e-4 costs the same total bytes as stepping through
    assert b2 == fresh.total_bytes_fetched
    assert b2 > b1


def test_serialization_roundtrip(field, refd):
    blob = rf.refactored_to_bytes(refd)
    r2 = rf.refactored_from_bytes(blob)
    a, _, _ = rt.ProgressiveReader(refd).retrieve(1e-3)
    b, _, _ = rt.ProgressiveReader(r2).retrieve(1e-3)
    assert np.array_equal(a, b)


def test_relative_tolerance(field, refd):
    reader = rt.ProgressiveReader(refd)
    xh, bound, _ = reader.retrieve(1e-3, relative=True)
    assert np.abs(xh - field).max() <= 1e-3 * refd.data_range


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10 ** 6), st.floats(1e-5, 1e-1))
def test_guarantee_property(seed, tol):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(12, 28, size=2))
    x = gaussian_field(shape, slope=float(rng.uniform(-3, -1.2)), seed=seed)
    r = rf.refactor_array(x, "p")
    xh, bound, _ = rt.ProgressiveReader(r).retrieve(float(tol))
    assert np.abs(xh - x).max() <= bound


# --------------------------------------------------------------------- QoI --

@pytest.mark.parametrize("method,kw", [("cp", {}), ("ma", {}),
                                       ("mape", {"c": 10.0})])
def test_qoi_error_control(method, kw):
    vs = list(velocity_field((24, 24, 24), seed=3))
    truth = sum(v ** 2 for v in vs)
    refs = [rf.refactor_array(v, f"v{i}") for i, v in enumerate(vs)]
    for tau in [1e-2, 1e-4]:
        readers = [rt.ProgressiveReader(r) for r in refs]
        res = qq.progressive_qoi_retrieve(readers, qq.V_TOTAL, tau,
                                          method=method, **kw)
        actual = float(np.abs(sum(v ** 2 for v in res.values) - truth).max())
        assert res.converged
        assert res.tau_estimated <= tau
        assert actual <= res.tau_estimated + 1e-12  # the paper's Fig-13 chain


@pytest.mark.parametrize("kind", ["sum_squares", "magnitude", "product", "linear"])
def test_qoi_estimators_conservative(kind):
    rng = np.random.default_rng(4)
    vs = [rng.normal(size=1000).astype(np.float32) for _ in range(3)]
    eps = [1e-3, 2e-3, 5e-4]
    vh = [v + rng.uniform(-e, e, size=v.shape).astype(np.float32)
          for v, e in zip(vs, eps)]
    q = qq.QoI(kind, coeffs=(1.0, -2.0, 0.5) if kind == "linear" else None)
    n = 2 if kind == "product" else 3
    est = np.asarray(qq.qoi_error_pointwise([jnp.asarray(v) for v in vh[:n]],
                                            eps[:n], q))
    actual = np.abs(np.asarray(qq.qoi_value(vs[:n], q))
                    - np.asarray(qq.qoi_value(vh[:n], q)))
    assert (actual <= est + 1e-7).all()


def test_qoi_floor_terminates_with_empty_pieces():
    """1-element arrays have empty detail pieces; an unreachable tau must
    stop at the floor instead of spinning to max_iters (at_floor is defined
    by peek_best, which skips unfetchable pieces)."""
    r = rf.refactor_array(np.full((1,), 0.5, np.float32), "s")
    readers = [rt.ProgressiveReader(r)]
    res = qq.progressive_qoi_retrieve(readers, qq.QoI("sum_squares"), 1e-30,
                                      method="ma", max_iters=100)
    assert not res.converged
    assert res.iterations < 20  # floor reached, loop exited early


def test_ma_bitrate_not_worse_than_cp():
    """The paper's ordering: MA retrieval efficiency >= CP (Tables 2/3)."""
    vs = list(velocity_field((32, 32, 32), seed=9))
    refs = [rf.refactor_array(v, f"v{i}") for i, v in enumerate(vs)]
    bitrates = {}
    for method in ["cp", "ma"]:
        readers = [rt.ProgressiveReader(r) for r in refs]
        res = qq.progressive_qoi_retrieve(readers, qq.V_TOTAL, 5e-4,
                                          method=method)
        bitrates[method] = res.bitrate
    assert bitrates["ma"] <= bitrates["cp"] * 1.05
