import os
import subprocess
import sys
import textwrap

import pytest

# The container has no `hypothesis`; fall back to the minimal deterministic
# shim in tests/_shims (same @given/@settings/strategies surface).  conftest
# is imported before any test module, so the path is in place in time.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                    "_shims"))

# NOTE: no XLA_FLAGS here on purpose — unit/smoke tests must see the real
# (single) device.  Multi-device tests spawn subprocesses via `run_devices`.

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(script: str, n_devices: int = 8, timeout: int = 900) -> str:
    """Run `script` in a subprocess with n host devices; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_devices}").strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\nSTDOUT:\n{r.stdout}\n"
                             f"STDERR:\n{r.stderr[-4000:]}")
    return r.stdout


@pytest.fixture
def subproc():
    return run_devices
