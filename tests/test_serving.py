"""Serving tier: shared plane cache, request coalescing, cross-session
batched decode, bounded prefetch queue, and stats-snapshot consistency.

The load-bearing contracts:

  * N concurrent sessions retrieving the same prefix issue exactly ONE
    backend read and ONE shared decode per plane group, and every session's
    reconstruction is byte-identical to the uncached single-session oracle;
  * an owner's fetch error propagates to every coalesced waiter (each
    applies its own degrade policy, per-session accounting) and is NEVER
    cached — the next requester retries fresh;
  * the plane cache admits by popularity (a cold scan cannot flush the hot
    set) and counts evictions/admission-rejects;
  * per-tenant fairness: one heavy session's backlog cannot monopolize a
    decode round;
  * SessionStats/BackendStats snapshots are internally consistent under
    concurrent mutation (the torn-read hammer);
  * session lifecycle across >= 8 threads with the chaos backend: no leaked
    sessions, no cross-session state bleed.
"""
import threading

import numpy as np
import pytest

from repro.core import qoi as qq
from repro.data.fields import gaussian_field
from repro.store import (CachingBackend, DatasetStore, DatasetWriter,
                         LocalFileBackend, RetrievalService, ServingTier)
from repro.store import backend as bk
from repro.store import reliability as rl
from repro.store import serving as sv
from repro.store.service import SessionStats


@pytest.fixture(scope="module")
def field():
    return gaussian_field((24, 24, 24), slope=-2.2, seed=7)


@pytest.fixture(scope="module")
def store_dir(tmp_path_factory, field):
    root = str(tmp_path_factory.mktemp("serving_store"))
    with DatasetWriter(root, chunk_elems=4000) as w:
        w.write("v", field)
    return root


@pytest.fixture(scope="module")
def oracle(store_dir):
    """Uncached single-session reference results per tolerance."""
    svc = RetrievalService(DatasetStore.open(store_dir), serving=False)
    out = {}
    for tol in (1e-2, 1e-3, 1e-4):
        # fresh session per tolerance: ``fetched`` is the full from-scratch
        # plan cost, comparable with cold sessions in the tests
        out[tol] = svc.open_session().retrieve("v", tol)
    return out


# -------------------------------------------------- coalescing correctness --

def test_concurrent_sessions_one_read_one_decode(store_dir, oracle):
    """The acceptance counter-test: N sessions, same tolerance, launched
    through a barrier — exactly one backend read and one shared decode per
    distinct plane group, all reconstructions byte-identical to the
    oracle."""
    backend = CachingBackend(LocalFileBackend(store_dir))
    store = DatasetStore.open(store_dir, backend=backend)
    svc = RetrievalService(store)
    N = 6
    tol = 1e-3
    sessions = [svc.open_session() for _ in range(N)]
    outs = [None] * N
    barrier = threading.Barrier(N)

    def run(k):
        barrier.wait()
        outs[k] = sessions[k].retrieve("v", tol)

    ts = [threading.Thread(target=run, args=(k,)) for k in range(N)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=300)
    assert all(o is not None for o in outs), "a session hung"

    ox, ob, of = oracle[tol]
    for k, (xk, bound, fetched) in enumerate(outs):
        np.testing.assert_array_equal(xk, ox, err_msg=f"session {k}")
        assert bound == ob
        # logical accounting: every session paid the same plan bytes,
        # regardless of where the decode actually ran
        assert fetched == of

    snap = svc.stats()
    tier, be = snap["serving"], snap["backend"]
    # each claim resolved exactly one way, and the tier decoded each
    # distinct group exactly once: everything else was a hit or coalesced
    assert tier["requests"] == N * tier["decoded"]
    assert tier["plane_hits"] + tier["coalesced"] + tier["decoded"] \
        == tier["requests"]
    assert tier["coalesced"] + tier["plane_hits"] > 0
    # exactly one backend fetch per decoded group (+1: the manifest read)
    assert be["fetches"] == tier["decoded"] + 1
    assert tier["errors_propagated"] == 0


def test_tolerance_tightening_across_sessions_matches_oracle(store_dir,
                                                             oracle):
    """Interleaved tightening schedules across sessions: every intermediate
    state byte-identical to the oracle, later sessions ride the cache."""
    svc = RetrievalService(DatasetStore.open(store_dir))
    a, b = svc.open_session(), svc.open_session()
    for s, tol in [(a, 1e-2), (b, 1e-3), (a, 1e-4), (b, 1e-4), (a, 1e-4)]:
        x, bound, _ = s.retrieve("v", tol)
        np.testing.assert_array_equal(x, oracle[tol][0])
        assert bound == oracle[tol][1]
    tier = svc.stats()["serving"]
    assert tier["plane_hits"] > 0            # b's groups served from cache
    assert tier["decoded"] < tier["requests"]


def test_cache_disabled_keeps_coalescing(store_dir, oracle):
    """plane_cache_bytes=0: no retention (second pass decodes again), but
    claims still dedupe and results stay byte-identical."""
    svc = RetrievalService(DatasetStore.open(store_dir),
                           plane_cache_bytes=0)
    a = svc.open_session()
    b = svc.open_session()
    xa, _, _ = a.retrieve("v", 1e-3)
    xb, _, _ = b.retrieve("v", 1e-3)
    np.testing.assert_array_equal(xa, oracle[1e-3][0])
    np.testing.assert_array_equal(xb, oracle[1e-3][0])
    tier = svc.stats()["serving"]
    assert tier["plane_hits"] == 0 and tier["admitted"] == 0
    assert tier["decoded"] == tier["requests"]  # sequential: no coalescing


def test_qoi_concurrent_sessions_share_tier(store_dir, field):
    """QoI retrieval through the tier: concurrent sessions converge and the
    result matches the tolerance the QoI loop negotiated."""
    svc = RetrievalService(DatasetStore.open(store_dir))
    res = [None, None]

    def run(k):
        s = svc.open_session()
        res[k] = s.retrieve_qoi(["v"], qq.V_TOTAL, 1e-2)

    ts = [threading.Thread(target=run, args=(k,)) for k in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=300)
    assert res[0] is not None and res[1] is not None
    assert res[0].converged and res[1].converged
    np.testing.assert_array_equal(res[0].values[0], res[1].values[0])


# ------------------------------------------------------- error propagation --

class _RangeFaultBackend(bk.FetchBackend):
    """Fails reads of registered byte ranges until ``heal()`` — the
    deterministic double for a persistently unreachable segment."""

    def __init__(self, inner: bk.FetchBackend):
        self.inner = inner
        self.failing: set = set()
        self.fail_reads = 0

    def fail_range(self, offset: int, size: int) -> None:
        self.failing.add((offset, size))

    def heal(self) -> None:
        self.failing.clear()

    def read(self, key: str, offset: int, size: int) -> bytes:
        if (offset, size) in self.failing:
            self.fail_reads += 1
            raise rl.TransientFetchError(f"injected: {key}@{offset}+{size}")
        return self.inner.read(key, offset, size)

    def size(self, key: str) -> int:
        return self.inner.size(key)

    def close(self) -> None:
        self.inner.close()


def test_error_propagates_to_all_waiters_never_cached(store_dir, oracle):
    """An owner's typed store failure reaches every coalesced session (each
    degrades under its OWN policy, with per-session accounting), nothing is
    cached for the failed key, and a later session retries fresh after the
    fault clears."""
    faulty = _RangeFaultBackend(LocalFileBackend(store_dir))
    store = DatasetStore.open(store_dir,
                              backend=CachingBackend(faulty))
    # fail one plane group that a 1e-3 plan certainly wants: chunk 0,
    # piece 1, group 0 (the cold set fetches group 0 of every piece)
    ref = store.variable("v").chunks[0].pieces[1].groups[0]
    faulty.fail_range(ref.offset, ref.size)

    svc = RetrievalService(store, degrade=True)
    N = 4
    sessions = [svc.open_session() for _ in range(N)]
    outs = [None] * N
    barrier = threading.Barrier(N)

    def run(k):
        barrier.wait()
        outs[k] = sessions[k].retrieve("v", 1e-3)

    ts = [threading.Thread(target=run, args=(k,)) for k in range(N)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=300)
    assert all(o is not None for o in outs)

    ox, ob, _ = oracle[1e-3]
    for k, (xk, bound, _) in enumerate(outs):
        # every session degraded the SAME piece: served without the group,
        # bound honestly widened past the oracle's
        assert bound > ob
        assert not np.array_equal(xk, ox)
    stats = svc.stats()
    # per-session accounting: each session recorded its own degradation
    for sid, st in stats["sessions"].items():
        assert st["degraded_groups"] >= 1, (sid, st)
    # the failure is never admitted to the plane cache
    assert stats["serving"]["plane_cache"]["entries"] \
        == stats["serving"]["admitted"]
    assert svc.tier.inflight_count == 0      # no wedged claims

    # fault clears: a FRESH session must get the exact result (the error
    # was propagated, not cached)
    faulty.heal()
    s = svc.open_session()
    x, bound, _ = s.retrieve("v", 1e-3)
    np.testing.assert_array_equal(x, ox)
    assert bound == ob
    assert svc.stats()["sessions"][s.sid]["degraded_groups"] == 0


def test_tier_fail_unit_semantics():
    """Claim-table unit contract: fail() resolves every coalesced waiter
    with the same error, and the key is immediately claimable again."""
    tier = ServingTier(window_s=0.0)
    key = ("v", 0, 1, 2)
    (kind, fut), = tier.claim(1, [key]).values()
    assert kind == "mine"
    (kind2, fut2), = tier.claim(2, [key]).values()
    assert kind2 == "theirs" and fut2 is fut

    got = {}

    def waiter():
        try:
            tier.wait_for(fut2)
        except Exception as exc:  # noqa: BLE001
            got["exc"] = exc

    t = threading.Thread(target=waiter)
    t.start()
    boom = rl.TransientFetchError("boom")
    tier.fail(key, boom)
    t.join(timeout=30)
    assert got["exc"] is boom
    # never cached; the next claimant owns a fresh attempt
    (kind3, _), = tier.claim(3, [key]).values()
    assert kind3 == "mine"
    assert tier.stats.snapshot()["errors_propagated"] == 1


def test_abandon_withdraws_queued_jobs():
    """abandon() fails claimed keys AND withdraws their queued decode jobs,
    so no thread decodes work nobody will consume."""
    tier = ServingTier(window_s=0.0)
    key = ("v", 0, 0, 0)
    (_, fut), = tier.claim(7, [key]).values()
    job = sv.DecodeJob(key=key, kind="group",
                       rows=np.zeros((2, 4), np.uint32), row_offset=0,
                       n=128, mag_bits=30, design="register_block",
                       backend="auto", tiles_per_block=8, unroll="naive",
                       device=None, future=fut)
    tier.submit(7, [job])
    tier.abandon(7, [key], RuntimeError("unwinding"))
    assert fut.done and isinstance(fut.error, RuntimeError)
    with tier._lock:
        assert not tier._queued()
    assert tier.inflight_count == 0


# ------------------------------------------------------------- plane cache --

def _planes(n_words: int) -> sv.DecodedPlanes:
    return sv.DecodedPlanes(array=np.zeros((n_words,), np.uint32),
                            kind="group", n_rows=1, row_bytes=4 * n_words)


def test_plane_cache_lru_eviction_and_bytes():
    c = sv.PlaneCache(capacity_bytes=100)          # 25 uint32 elements
    for k in ("a", "b"):
        c.touch((k, 0, 0, 0))
        assert c.offer((k, 0, 0, 0), _planes(10))[0]
    assert c.cached_bytes == 80 and len(c) == 2
    # same-popularity insert evicts the LRU head
    c.touch(("c", 0, 0, 0))
    admitted, evictions, rejects = c.offer(("c", 0, 0, 0), _planes(10))
    assert admitted and evictions == 1 and rejects == 0
    assert c.get(("a", 0, 0, 0)) is None           # a was LRU
    assert c.get(("b", 0, 0, 0)) is not None


def test_plane_cache_popularity_guards_hot_set():
    """TinyLFU-style admission: a one-hit-wonder cannot evict an entry more
    popular than itself — the cold scan bounces off the hot set."""
    c = sv.PlaneCache(capacity_bytes=80)           # room for two entries
    hot = ("hot", 0, 0, 0)
    for _ in range(10):
        c.touch(hot)
    assert c.offer(hot, _planes(10))[0]
    warm = ("warm", 0, 0, 0)
    c.touch(warm)
    assert c.offer(warm, _planes(10))[0]
    c.get(warm)   # LRU order now: hot, warm — hot is the eviction victim
    cold = ("cold", 0, 0, 0)
    c.touch(cold)
    admitted, evictions, rejects = c.offer(cold, _planes(10))
    assert not admitted and rejects == 1 and evictions in (0, 1)
    assert c.get(hot) is not None                  # hot set survived
    assert c.get(cold) is None


def test_plane_cache_oversized_candidate_rejected():
    c = sv.PlaneCache(capacity_bytes=30)
    big = ("big", 0, 0, 0)
    c.touch(big)
    admitted, _, rejects = c.offer(big, _planes(100))   # 400 bytes > cap
    assert not admitted and rejects == 1
    assert len(c) == 0 and c.cached_bytes == 0


# ---------------------------------------------------------------- fairness --

def test_fair_batch_round_robins_tenants():
    """A heavy tenant's backlog cannot monopolize a decode round: the batch
    interleaves every tenant's queue and overflow waits."""
    tier = ServingTier(window_s=0.0, max_batch_jobs=4)

    def job(tenant, i):
        key = (f"t{tenant}", 0, 0, i)
        (_, fut), = tier.claim(tenant, [key]).values()
        return sv.DecodeJob(key=key, kind="group",
                            rows=np.zeros((1, 4), np.uint32), row_offset=0,
                            n=64, mag_bits=30, design="register_block",
                            backend="auto", tiles_per_block=8,
                            unroll="naive", device=None, future=fut)

    tier.submit(1, [job(1, i) for i in range(10)])   # heavy
    tier.submit(2, [job(2, i) for i in range(2)])    # light
    with tier._lock:
        batch = tier._take_fair_batch()
    owners = [j.key[0] for j in batch]
    assert owners == ["t1", "t2", "t1", "t2"]        # strict interleave
    with tier._lock:
        rest = tier._take_fair_batch()
    assert [j.key[0] for j in rest] == ["t1"] * 4    # overflow next round


# -------------------------------------------------- bounded prefetch queue --

def test_prefetch_queue_bounded_drops_oldest():
    """A prefetch storm cannot grow the queue without limit: the stalest
    hints are shed first and counted (stats + obs metric)."""
    gate = threading.Event()

    class _Slow(bk.FetchBackend):
        def read(self, key, offset, size):
            gate.wait(timeout=30)
            return b"\0" * size

        def size(self, key):
            return 1 << 20

    be = CachingBackend(_Slow(), workers=1, prefetch_queue_max=4)
    try:
        for i in range(20):
            be.prefetch("k", i * 10, 10)
        snap = be.stats.snapshot()
        assert snap["prefetch_issued"] == 20
        assert snap["prefetch_dropped"] >= 14     # 20 - worker(1) - queue(4)
        with be._lock:
            assert len(be._queue) <= 4
    finally:
        gate.set()
        be.close()


# ----------------------------------------------------- stats snapshot race --

def test_session_stats_snapshot_hammer():
    """Snapshots taken mid-update are internally consistent: every add() is
    atomic, so bytes_fetched == 7 * requests in EVERY observed snapshot."""
    st = SessionStats()
    stop = threading.Event()
    bad = []

    def writer():
        while not stop.is_set():
            st.add(requests=1, bytes_fetched=7, qoi_iterations=2)

    def reader():
        while not stop.is_set():
            s = st.snapshot()
            if s["bytes_fetched"] != 7 * s["requests"] \
                    or s["qoi_iterations"] != 2 * s["requests"]:
                bad.append(s)

    ts = [threading.Thread(target=writer) for _ in range(4)] \
        + [threading.Thread(target=reader) for _ in range(2)]
    for t in ts:
        t.start()
    import time
    time.sleep(0.5)
    stop.set()
    for t in ts:
        t.join(timeout=30)
    assert not bad, bad[:3]
    final = st.snapshot()
    assert final["bytes_fetched"] == 7 * final["requests"]


def test_backend_stats_snapshot_hammer():
    st = bk.BackendStats()
    stop = threading.Event()
    bad = []

    def writer():
        while not stop.is_set():
            st.add(reads=1, bytes_served=13, cache_hits=1)

    def reader():
        while not stop.is_set():
            s = st.snapshot()
            if s["bytes_served"] != 13 * s["reads"] \
                    or s["cache_hits"] != s["reads"]:
                bad.append(s)

    ts = [threading.Thread(target=writer) for _ in range(4)] \
        + [threading.Thread(target=reader) for _ in range(2)]
    for t in ts:
        t.start()
    import time
    time.sleep(0.5)
    stop.set()
    for t in ts:
        t.join(timeout=30)
    assert not bad, bad[:3]


# ----------------------------------------------------- lifecycle under chaos --

def test_session_lifecycle_concurrent_chaos(store_dir, oracle, monkeypatch):
    """Create/retrieve/close across 8 threads with the chaos backend wired
    in (REPRO_CHAOS): every result byte-identical to the oracle through
    retries, no leaked sessions, no cross-session bleed, and degraded
    accounting stays zero (transient faults are retried, not degraded)."""
    monkeypatch.setenv("REPRO_CHAOS", "transient=0.05,seed=97")
    store = DatasetStore.open(store_dir)   # default backend: chaos-wrapped
    svc = RetrievalService(store)
    N = 8
    errors = []
    barrier = threading.Barrier(N)

    def run(k):
        barrier.wait()
        try:
            for tol in (1e-2, 1e-3):
                s = svc.open_session()
                try:
                    x, bound, _ = s.retrieve("v", tol)
                    ox, ob, _ = oracle[tol]
                    if not np.array_equal(x, ox):
                        errors.append((k, tol, "bytes"))
                    if bound != ob:
                        errors.append((k, tol, "bound"))
                    if s.stats.snapshot()["degraded_groups"] != 0:
                        errors.append((k, tol, "degraded"))
                finally:
                    svc.close_session(s)
        except Exception as exc:  # noqa: BLE001
            errors.append((k, repr(exc)))

    ts = [threading.Thread(target=run, args=(k,)) for k in range(N)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=600)
    assert not errors, errors[:5]
    assert svc.sessions == []              # every session closed: no leaks
    assert svc.tier.inflight_count == 0    # no wedged claims
