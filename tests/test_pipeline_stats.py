"""Chunked-pipeline regressions: empty-input handling and async-dispatch-safe
stage timing (PipelineStats must attribute execution, not dispatch)."""
import numpy as np

from repro.core.pipeline import (ChunkedReconstructPipeline,
                                 ChunkedRefactorPipeline)
from repro.data.fields import gaussian_field


def test_reconstruct_empty_blob_list():
    """Regression: np.concatenate([]) used to raise ValueError."""
    p = ChunkedReconstructPipeline(pipelined=False)
    out = p.reconstruct([], tol=1e-3)
    assert out.shape == (0,) and out.dtype == np.float32
    p2 = ChunkedReconstructPipeline(pipelined=True)
    assert p2.reconstruct([], tol=1e-3).shape == (0,)


def test_empty_array_through_both_pipelines():
    blobs = ChunkedRefactorPipeline(pipelined=False).refactor(
        np.zeros((0,), np.float32), "e")
    out = ChunkedReconstructPipeline(pipelined=False).reconstruct(blobs, 1e-3)
    assert out.shape == (0,)


def test_serial_stage_times_sum_to_wall():
    """In serial mode every stage blocks before its timer stops, so
    copy_in + compute + copy_out must account for ~all of wall_s; async
    dispatch leaking execution across stage boundaries would break this."""
    x = gaussian_field((64, 64, 8), slope=-2.0, seed=2)
    p = ChunkedRefactorPipeline(chunk_elems=1 << 14, pipelined=False,
                                levels=2)
    blobs = p.refactor(x, "v")
    st = p.stats
    ssum = st.copy_in_s + st.compute_s + st.copy_out_s
    assert ssum <= st.wall_s * 1.01
    assert ssum >= 0.6 * st.wall_s, (ssum, st.wall_s)

    r = ChunkedReconstructPipeline(pipelined=False)
    out = r.reconstruct(blobs, tol=1e-4)
    assert np.abs(out - x.reshape(-1)).max() <= 1e-4
    rs = r.stats
    rsum = rs.copy_in_s + rs.compute_s + rs.copy_out_s
    assert rsum <= rs.wall_s * 1.01
    assert rsum >= 0.6 * rs.wall_s, (rsum, rs.wall_s)
