"""Chunked-pipeline regressions: empty-input handling, async-dispatch-safe
stage timing (PipelineStats must attribute execution, not dispatch), and
overlap_map depth>1 ordering/exception contracts."""
import threading
import time

import numpy as np
import pytest

from repro.core.pipeline import (ChunkedReconstructPipeline,
                                 ChunkedRefactorPipeline, overlap_map)
from repro.data.fields import gaussian_field


def test_reconstruct_empty_blob_list():
    """Regression: np.concatenate([]) used to raise ValueError."""
    p = ChunkedReconstructPipeline(pipelined=False)
    out = p.reconstruct([], tol=1e-3)
    assert out.shape == (0,) and out.dtype == np.float32
    p2 = ChunkedReconstructPipeline(pipelined=True)
    assert p2.reconstruct([], tol=1e-3).shape == (0,)


def test_empty_array_through_both_pipelines():
    blobs = ChunkedRefactorPipeline(pipelined=False).refactor(
        np.zeros((0,), np.float32), "e")
    out = ChunkedReconstructPipeline(pipelined=False).reconstruct(blobs, 1e-3)
    assert out.shape == (0,)


@pytest.mark.parametrize("depth", [2, 3, 7])
def test_overlap_map_depth_preserves_order(depth):
    """The feeder may run ``depth`` items ahead; results must still land in
    order even when stage-1 latencies are adversarial."""
    rng = np.random.default_rng(depth)
    delays = rng.uniform(0, 0.004, 12)
    seen_ahead = []

    def stage1(i):
        time.sleep(delays[i])
        return i * 10

    done = [-1]

    def stage2(i, s1):
        seen_ahead.append(i - done[0])
        done[0] = i
        time.sleep(0.002)
        assert s1 == i * 10
        return i

    out = overlap_map(12, stage1, stage2, pipelined=True, depth=depth)
    assert out == list(range(12))
    assert all(a == 1 for a in seen_ahead)  # stage2 strictly in order


@pytest.mark.parametrize("depth", [2, 4])
def test_overlap_map_depth_stage1_exception_propagates(depth):
    def stage1(i):
        if i == 5:
            raise ValueError("feeder boom")
        return i

    with pytest.raises(ValueError, match="feeder boom"):
        overlap_map(10, stage1, lambda i, s: s, pipelined=True, depth=depth)


@pytest.mark.parametrize("depth", [2, 4])
def test_overlap_map_depth_stage2_exception_stops_feeder(depth):
    started = []
    threads_before = threading.active_count()

    def stage1(i):
        started.append(i)
        return i

    def stage2(i, s):
        if i == 3:
            raise RuntimeError("consumer boom")
        return s

    with pytest.raises(RuntimeError, match="consumer boom"):
        overlap_map(50, stage1, stage2, pipelined=True, depth=depth)
    # the feeder was cancelled: it ran at most depth-ish items past the
    # failure point, not all 50, and its thread exited (no leak)
    assert max(started) <= 3 + depth + 2
    deadline = time.time() + 5
    while threading.active_count() > threads_before and time.time() < deadline:
        time.sleep(0.01)
    assert threading.active_count() <= threads_before


def test_reconstruct_pipeline_depth_matches_serial():
    x = gaussian_field((64, 64, 8), slope=-2.0, seed=4)
    blobs = ChunkedRefactorPipeline(chunk_elems=1 << 13, pipelined=False,
                                    levels=2).refactor(x, "v")
    base = ChunkedReconstructPipeline(pipelined=False).reconstruct(blobs, 1e-4)
    for depth in (1, 3):
        p = ChunkedReconstructPipeline(pipelined=True, depth=depth)
        assert np.array_equal(p.reconstruct(blobs, 1e-4), base)


def test_retrieval_service_depth_plumbs_through(tmp_path):
    from repro.store import DatasetStore, DatasetWriter, RetrievalService
    x = gaussian_field((24, 24, 8), slope=-2.0, seed=6)
    root = str(tmp_path / "store")
    with DatasetWriter(root, chunk_elems=1 << 10) as w:
        w.write("v", x)
    svc = RetrievalService(DatasetStore.open(root), depth=4)
    s = svc.open_session()
    assert s.reader("v").depth == 4
    xh, bound, _ = s.retrieve("v", 1e-4)
    assert float(np.abs(xh - x).max()) <= bound <= 1e-4


def test_serial_stage_times_sum_to_wall():
    """In serial mode every stage blocks before its timer stops, so
    copy_in + compute + copy_out must account for ~all of wall_s; async
    dispatch leaking execution across stage boundaries would break this."""
    x = gaussian_field((64, 64, 8), slope=-2.0, seed=2)
    p = ChunkedRefactorPipeline(chunk_elems=1 << 14, pipelined=False,
                                levels=2)
    blobs = p.refactor(x, "v")
    st = p.stats
    ssum = st.copy_in_s + st.compute_s + st.copy_out_s
    assert ssum <= st.wall_s * 1.01
    assert ssum >= 0.6 * st.wall_s, (ssum, st.wall_s)

    r = ChunkedReconstructPipeline(pipelined=False)
    out = r.reconstruct(blobs, tol=1e-4)
    assert np.abs(out - x.reshape(-1)).max() <= 1e-4
    rs = r.stats
    rsum = rs.copy_in_s + rs.compute_s + rs.copy_out_s
    assert rsum <= rs.wall_s * 1.01
    assert rsum >= 0.6 * rs.wall_s, (rsum, rs.wall_s)
