"""Progressive store + retrieval service: on-disk layout, byte-range
addressing, caching backend accounting, concurrent sessions, QoI serving."""
import json
import os
import threading

import numpy as np
import pytest

from repro.core import qoi as qq
from repro.data.fields import gaussian_field, velocity_field
from repro.store import (CachingBackend, DatasetStore, DatasetWriter,
                         InMemoryBackend, LocalFileBackend, RetrievalService)
from repro.store import layout as lo
from repro.store import reliability as rl


@pytest.fixture(scope="module")
def field():
    return gaussian_field((36, 36, 36), slope=-2.2, seed=11)


@pytest.fixture(scope="module")
def store_dir(tmp_path_factory, field):
    root = str(tmp_path_factory.mktemp("store"))
    with DatasetWriter(root, chunk_elems=16000) as w:
        w.write("v", field)
    return root


def test_manifest_layout(store_dir, field):
    with open(os.path.join(store_dir, lo.MANIFEST_NAME)) as f:
        j = json.load(f)
    man = lo.Manifest.from_json(j)
    v = man.variables["v"]
    assert v.shape == field.shape
    assert len(v.chunks) == -(-field.size // 16000)
    seg_size = os.path.getsize(lo.segment_path(store_dir, v.segment_file))
    # byte ranges tile the segment file exactly: no gaps, no overlaps
    ranges = sorted((g.offset, g.size)
                    for c in v.chunks for p in c.pieces
                    for g in [p.sign] + p.groups)
    pos = 0
    for off, size in ranges:
        assert off == pos
        pos += size
    assert pos == seg_size == v.stored_bytes


def test_cold_incremental_tolerance_sequence(store_dir, field):
    """Acceptance: cold open, 1e-2 -> 1e-3 -> 1e-4, delta fetches only,
    bytes monotone and < full store at loose tolerances, bounds honored."""
    store = DatasetStore.open(store_dir)
    svc = RetrievalService(store)
    s = svc.open_session()
    total_prev = 0
    for tol in [1e-2, 1e-3, 1e-4]:
        xh, bound, fetched = s.retrieve("v", tol)
        err = float(np.abs(xh - field).max())
        assert err <= bound <= tol, (tol, err, bound)
        assert s.bytes_fetched == total_prev + fetched
        assert s.bytes_fetched > total_prev       # tighter tol -> more bytes
        total_prev = s.bytes_fetched
        assert s.bytes_fetched < store.stored_bytes
    # re-request at an already-met tolerance: zero new bytes
    _, _, fetched = s.retrieve("v", 1e-3)
    assert fetched == 0
    # stepping through tolerances costs the same total as going direct
    s2 = svc.open_session()
    s2.retrieve("v", 1e-4)
    assert s2.bytes_fetched == s.bytes_fetched


def test_backend_cache_accounting(store_dir):
    backend = CachingBackend(LocalFileBackend(store_dir))
    store = DatasetStore.open(store_dir, backend=backend)
    # serving=False: the subject here is the BYTE cache; the plane cache
    # above it would serve repeat sessions without touching the backend
    svc = RetrievalService(store, serving=False)
    svc.open_session().retrieve("v", 1e-3)
    cold = backend.stats.bytes_fetched
    assert cold > 0 and backend.stats.cache_misses > 0
    # a second session re-reads the same ranges: served from cache
    svc.open_session().retrieve("v", 1e-3)
    assert backend.stats.bytes_fetched == cold
    assert backend.stats.cache_hits > 0
    # dropping the cache forces re-fetch
    backend.drop_cache()
    svc.open_session().retrieve("v", 1e-3)
    assert backend.stats.bytes_fetched > cold


def test_in_memory_backend_roundtrip(store_dir, field):
    with open(os.path.join(store_dir, lo.MANIFEST_NAME)) as f:
        seg_key = lo.Manifest.from_json(json.load(f)).variables["v"].segment_file
    buffers = {}
    for name in [lo.MANIFEST_NAME, seg_key]:
        with open(lo.segment_path(store_dir, name) if "/" in name
                  else os.path.join(store_dir, name), "rb") as f:
            buffers[name] = f.read()
    store = DatasetStore.open(store_dir, backend=InMemoryBackend(buffers))
    xh, bound, _ = RetrievalService(store).open_session().retrieve("v", 1e-3)
    assert float(np.abs(xh - field).max()) <= bound <= 1e-3


def test_planner_sees_true_range_sizes(store_dir):
    store = DatasetStore.open(store_dir)
    v = store.variable("v")
    refd = lo.chunk_refactored(v, 0)
    for pm, pe in zip(refd.pieces, v.chunks[0].pieces):
        assert pm.sign_seg.is_stub and pm.sign_seg.stored_bytes == pe.sign.size
        for g, gr in zip(pm.groups, pe.groups):
            assert g.is_stub and g.stored_bytes == gr.size


def test_retrieve_many_batches_across_sessions(store_dir, field):
    store = DatasetStore.open(store_dir)
    svc = RetrievalService(store)
    s1, s2 = svc.open_session(), svc.open_session()
    (x1, b1, f1), (x2, b2, f2) = svc.retrieve_many(
        [(s1, "v", 1e-3), (s2, "v", 1e-4)])
    assert float(np.abs(x1 - field).max()) <= b1 <= 1e-3
    assert float(np.abs(x2 - field).max()) <= b2 <= 1e-4
    # batched result identical to the single-session path
    s3 = RetrievalService(DatasetStore.open(store_dir)).open_session()
    x3, b3, f3 = s3.retrieve("v", 1e-3)
    assert np.array_equal(x1, x3) and b1 == b3 and f1 == f3


def test_retrieve_many_duplicate_requests_account_once(store_dir, field):
    svc = RetrievalService(DatasetStore.open(store_dir))
    s = svc.open_session()
    (x1, b1, f1), (x2, b2, f2) = svc.retrieve_many(
        [(s, "v", 1e-3), (s, "v", 1e-4)])
    # duplicates share reader state: both get the tightest reconstruction,
    # bytes are attributed exactly once
    assert b1 <= 1e-4 and b2 <= 1e-4 and np.array_equal(x1, x2)
    assert f1 > 0 and f2 == 0
    assert s.bytes_fetched == f1 == s.reader("v").total_bytes_fetched


def test_met_tolerance_rerequest_skips_decode(store_dir):
    s = RetrievalService(DatasetStore.open(store_dir)).open_session()
    x1, _, _ = s.retrieve("v", 1e-3)
    x2, _, fetched = s.retrieve("v", 1e-3)
    assert fetched == 0
    assert x2 is x1  # served from the reconstruction cache, no re-decode


def test_qoi_concurrent_sessions(tmp_path):
    vs = list(velocity_field((20, 20, 20), seed=3))
    truth = sum(v ** 2 for v in vs)
    root = str(tmp_path / "qoi_store")
    with DatasetWriter(root, chunk_elems=1 << 20) as w:
        for n, v in zip(["vx", "vy", "vz"], vs):
            w.write(n, v)
    svc = RetrievalService(DatasetStore.open(root))

    results = []
    def client():
        s = svc.open_session()
        for tau in [1e-2, 1e-4]:
            before = s.bytes_fetched
            res = s.retrieve_qoi(["vx", "vy", "vz"], qq.V_TOTAL, tau)
            actual = float(np.abs(sum(v ** 2 for v in res.values) - truth).max())
            results.append((tau, res.converged, res.tau_estimated, actual,
                            s.bytes_fetched - before))

    threads = [threading.Thread(target=client) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(results) == 4
    for tau, converged, tau_est, actual, delta in results:
        assert converged and actual <= tau_est <= tau
        assert delta > 0  # each tightening fetched only a (nonzero) delta


def test_multi_variable_and_chunk_edges(tmp_path):
    root = str(tmp_path / "edges")
    arrs = {
        "a": gaussian_field((2000,), seed=1),       # chunk | n with remainder
        "b": gaussian_field((9, 9), seed=2),        # single small chunk
        "scalar": np.float32(3.25).reshape(()),     # 0-d
        "empty": np.zeros((0,), np.float32),        # no chunks at all
    }
    with DatasetWriter(root, chunk_elems=750) as w:
        for k, v in arrs.items():
            w.write(k, np.asarray(v))
    store = DatasetStore.open(root)
    assert sorted(store.variables) == sorted(arrs)
    s = RetrievalService(store).open_session()
    for k, v in arrs.items():
        xh, bound, _ = s.retrieve(k, 1e-4)
        assert xh.shape == np.asarray(v).shape
        if np.asarray(v).size:
            assert float(np.abs(xh - v).max()) <= bound <= 1e-4


def test_rewrite_merges_committed_manifest(tmp_path):
    """Writing into an existing store adds/replaces variables; untouched
    committed variables survive."""
    root = str(tmp_path / "merge")
    xa = gaussian_field((20, 20), seed=1)
    xb = gaussian_field((20, 20), seed=2)
    with DatasetWriter(root, chunk_elems=1 << 20) as w:
        w.write("a", xa)
        w.write("b", xb)
    with DatasetWriter(root, chunk_elems=1 << 20) as w:
        w.write("a", (xa * 3).astype(np.float32))  # rewrite one variable
    store = DatasetStore.open(root)
    assert sorted(store.variables) == ["a", "b"]
    s = RetrievalService(store).open_session()
    xh_a, ba, _ = s.retrieve("a", 1e-4)
    xh_b, bb, _ = s.retrieve("b", 1e-4)
    assert float(np.abs(xh_a - xa * 3).max()) <= ba  # new generation
    assert float(np.abs(xh_b - xb).max()) <= bb      # untouched survivor


def test_interrupted_rewrite_keeps_old_store_consistent(tmp_path):
    """A writer that dies before finalize() must not corrupt the committed
    store: new generations land in fresh segment files, the old manifest
    keeps addressing the old ones."""
    root = str(tmp_path / "rw")
    x = gaussian_field((30, 30), seed=5)
    with DatasetWriter(root, chunk_elems=1 << 20) as w:
        w.write("v", x)
    w2 = DatasetWriter(root, chunk_elems=1 << 20)
    w2.write("v", (x * 2).astype(np.float32))  # crash: finalize never runs
    s = RetrievalService(DatasetStore.open(root)).open_session()
    xh, bound, _ = s.retrieve("v", 1e-4)
    assert float(np.abs(xh - x).max()) <= bound  # still the OLD data
    # completing the rewrite commits the new generation
    w2.finalize()
    s2 = RetrievalService(DatasetStore.open(root)).open_session()
    xh2, bound2, _ = s2.retrieve("v", 1e-4)
    assert float(np.abs(xh2 - x * 2).max()) <= bound2


def test_relative_tolerance_uses_global_range(store_dir, field):
    store = DatasetStore.open(store_dir)
    s = RetrievalService(store).open_session()
    xh, bound, _ = s.retrieve("v", 1e-3, relative=True)
    rng = float(field.max() - field.min())
    assert float(np.abs(xh - field).max()) <= 1e-3 * rng


def test_write_duplicate_name_raises(tmp_path, field):
    """A second write of the same name in one writer session must raise, not
    silently replace the first's manifest entry (and orphan its segments)."""
    root = str(tmp_path / "dup")
    with DatasetWriter(root, chunk_elems=16000) as w:
        w.write("v", field)
        with pytest.raises(ValueError, match="already written"):
            w.write("v", field * 2)
        with pytest.raises(ValueError, match="invalid variable name"):
            w.write("", field)
        w.write("u", field[0])  # the writer stays usable after the errors
    store = DatasetStore.open(root)
    assert sorted(store.variables) == ["u", "v"]
    s = RetrievalService(store).open_session()
    xh, bound, _ = s.retrieve("v", 1e-3)
    assert float(np.abs(xh - field).max()) <= bound  # first write's data won


# ----------------------------------------------------- manifest plan compat --

def test_manifest_records_write_plan(store_dir):
    """Every variable written by today's writer carries its effective
    RefactorConfig as ``plan``; the reader replays it."""
    from repro import tune as tn
    store = DatasetStore.open(store_dir)
    v = store.variable("v")
    assert v.plan is not None
    cfg = tn.RefactorConfig.from_json(v.plan)
    assert cfg.design == v.design and cfg.group_size == v.group_size
    r = RetrievalService(store).open_session().reader("v")
    assert r.plan_config == tn.as_config(cfg)


def test_pre_plan_manifest_loads_and_serves(tmp_path, field):
    """Back compat: stores written before ``plan`` (and before ``shards``)
    existed must load and serve identically."""
    root = str(tmp_path / "legacy")
    with DatasetWriter(root, chunk_elems=16000) as w:
        w.write("v", field)
    s = RetrievalService(DatasetStore.open(root)).open_session()
    x_new, b_new, f_new = s.retrieve("v", 1e-3)
    # doctor the committed manifest back to the pre-plan schema
    mpath = os.path.join(root, lo.MANIFEST_NAME)
    with open(mpath) as f:
        j = json.load(f)
    j.pop("crc32", None)  # pre-integrity manifests carry no body checksum
    for v in j["variables"].values():
        v.pop("plan", None)
        v.pop("shards", None)
        # pre-checksum GroupRefs were 3-element [offset, size, method] lists
        for c in v["chunks"]:
            for p in c["pieces"]:
                p["sign"] = p["sign"][:3]
                p["groups"] = [g[:3] for g in p["groups"]]
    with open(mpath, "w") as f:
        json.dump(j, f)
    store = DatasetStore.open(root)
    assert store.variable("v").plan is None
    assert store.variable("v").shards is None
    assert store.variable("v").chunks[0].pieces[0].sign.crc is None
    x_old, b_old, f_old = (RetrievalService(store).open_session()
                           .retrieve("v", 1e-3))
    assert np.array_equal(x_old, x_new) and b_old == b_new and f_old == f_new


def test_unknown_manifest_keys_ignored(tmp_path, field):
    """Forward compat: a store written by NEWER code (extra keys at the
    manifest, variable, and plan levels) must stay readable."""
    root = str(tmp_path / "future")
    with DatasetWriter(root, chunk_elems=16000) as w:
        w.write("v", field)
    mpath = os.path.join(root, lo.MANIFEST_NAME)
    with open(mpath) as f:
        j = json.load(f)
    j["future_top_level"] = {"a": 1}
    for v in j["variables"].values():
        v["future_variable_key"] = [1, 2, 3]
        v["plan"]["future_knob"] = "x"  # unknown config field
    # a newer WRITER would have computed the body checksum over its own
    # extended variables body — recompute it the same way
    j["crc32"] = rl.manifest_body_checksum(j["variables"])
    with open(mpath, "w") as f:
        json.dump(j, f)
    store = DatasetStore.open(root)
    s = RetrievalService(store).open_session()
    xh, bound, _ = s.retrieve("v", 1e-3)
    assert float(np.abs(xh - field).max()) <= bound <= 1e-3


def test_variable_entry_plan_roundtrip_property():
    """Round-trip property for the ``plan`` field: to_json/from_json is the
    identity on any config the tuner can produce, and ``plan=None`` never
    emits the key (so old readers of new stores see the old schema shape)."""
    from hypothesis import given, settings, strategies as st
    from repro import tune as tn

    base = lo.VariableEntry(
        name="v", shape=(8,), levels=1, design="register_block", mag_bits=30,
        group_size=4, chunk_elems=8, segment_file="segments/v.seg",
        amax=1.0, range=2.0, chunks=[])
    assert "plan" not in base.to_json()
    assert lo.VariableEntry.from_json(base.to_json()).plan is None

    @settings(max_examples=30, deadline=None)
    @given(st.sampled_from(("register_block", "locality", "shuffle")),
           st.sampled_from((4, 8, 16)),
           st.sampled_from(("naive", "butterfly")),
           st.sampled_from((2, 4, 8)),
           st.integers(1, 4))
    def check(design, tiles, unroll, gs, depth):
        cfg = tn.RefactorConfig(design=design, tiles_per_block=tiles,
                                unroll=unroll, group_size=gs, depth=depth)
        import dataclasses
        e = dataclasses.replace(base, plan=cfg.to_json())
        j = e.to_json()
        back = lo.VariableEntry.from_json(json.loads(json.dumps(j)))
        assert back.plan == cfg.to_json()
        assert tn.RefactorConfig.from_json(back.plan) == cfg

    check()


def test_groupref_crc_compat_roundtrip_property():
    """Checksum-field compat, property-tested like ``shards``/``plan``:
    a crc-bearing GroupRef round-trips; a 3-element (pre-checksum) list
    parses with crc=None; and the first three elements of a new writer's
    4-element list are exactly what an old reader consumed."""
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2 ** 32 - 1), st.integers(0, 1 << 40),
           st.integers(0, 1 << 24),
           st.sampled_from(("dc", "huffman", "huffman+rle")))
    def check(crc, off, size, method):
        new = lo.GroupRef(off, size, method, crc)
        j = json.loads(json.dumps(new.to_json()))
        assert lo.GroupRef.from_json(j) == new
        assert len(j) == 4
        # old reader view: positional [offset, size, method] prefix
        old = lo.GroupRef.from_json(j[:3])
        assert (old.offset, old.size, old.method) == (off, size, method)
        assert old.crc is None
        pre = lo.GroupRef(off, size, method)  # pre-checksum writer
        assert len(pre.to_json()) == 3
        assert lo.GroupRef.from_json(pre.to_json()) == pre

    check()


def test_checksum_detects_segment_byte_flip(store_dir, field):
    """A flipped byte anywhere in a stored range surfaces as a typed
    CorruptSegmentError at read time (verify=True default); verify=False
    restores the pre-checksum behavior."""
    import shutil
    root = store_dir + "_flip"
    if os.path.exists(root):
        shutil.rmtree(root)
    shutil.copytree(store_dir, root)
    store = DatasetStore.open(root)
    v = store.variable("v")
    ref = v.chunks[0].pieces[0].groups[0]
    assert ref.crc is not None
    seg_path = lo.segment_path(root, v.segment_file)
    with open(seg_path, "r+b") as f:
        f.seek(ref.offset + ref.size // 2)
        b = f.read(1)
        f.seek(ref.offset + ref.size // 2)
        f.write(bytes([b[0] ^ 0x40]))
    store.backend.drop_cache()
    with pytest.raises(rl.CorruptSegmentError):
        store.read_segment("v", ref)
    store.close()
    unchecked = DatasetStore.open(root, verify=False)
    try:  # without verification the flip reaches the decoder as before:
        unchecked.read_segment("v", ref)  # framing may or may not notice
    except ValueError:
        pass
    finally:
        unchecked.close()


def test_manifest_body_checksum_detects_tamper(store_dir):
    with open(os.path.join(store_dir, lo.MANIFEST_NAME)) as f:
        j = json.load(f)
    assert "crc32" in j
    lo.Manifest.from_json(json.loads(json.dumps(j)))  # intact -> loads
    v = next(iter(j["variables"].values()))
    v["chunks"][0]["pieces"][0]["groups"][0][1] += 1  # rewrite a size
    with pytest.raises(rl.CorruptSegmentError):
        lo.Manifest.from_json(j)


def test_writer_checksums_off_is_pre_checksum_store(tmp_path, field):
    """checksums=False writes 3-element GroupRefs (the pre-checksum schema);
    the store loads and serves with verification skipped."""
    root = str(tmp_path / "nocrc")
    with DatasetWriter(root, chunk_elems=16000, checksums=False) as w:
        w.write("v", field)
    with open(os.path.join(root, lo.MANIFEST_NAME)) as f:
        j = json.load(f)
    for v in j["variables"].values():
        for c in v["chunks"]:
            for p in c["pieces"]:
                assert len(p["sign"]) == 3
                assert all(len(g) == 3 for g in p["groups"])
    store = DatasetStore.open(root)
    assert store.variable("v").chunks[0].pieces[0].sign.crc is None
    xh, bound, _ = RetrievalService(store).open_session().retrieve("v", 1e-3)
    assert float(np.abs(xh - field).max()) <= bound <= 1e-3


def test_store_mesh_roundtrip_across_device_counts(subproc):
    """Write with mesh= on 4 host devices, reopen and retrieve on 1 device
    (and vice versa): payloads bit-identical, tolerances honored, and the
    manifest's shard map records the round-robin placement."""
    subproc("""
        import json, os, tempfile
        import numpy as np, jax
        assert len(jax.devices()) == 4
        from repro.core import sharded as shd
        from repro.store import DatasetStore, DatasetWriter, RetrievalService
        from repro.store import layout as lo
        x = np.random.default_rng(7).standard_normal((40, 40, 40)).astype(np.float32)
        mesh = shd.make_chunk_mesh(4)
        with tempfile.TemporaryDirectory() as d:
            r1, r4 = os.path.join(d, "one"), os.path.join(d, "four")
            with DatasetWriter(r1, chunk_elems=9000) as w:
                w.write("v", x)
            with DatasetWriter(r4, chunk_elems=9000, mesh=mesh) as w:
                w.write("v", x)
            # on-disk payloads are byte-identical regardless of device count
            def seg(root):
                with open(os.path.join(root, lo.MANIFEST_NAME)) as f:
                    man = lo.Manifest.from_json(json.load(f))
                v = man.variables["v"]
                with open(lo.segment_path(root, v.segment_file), "rb") as f:
                    return v, f.read()
            v1, b1 = seg(r1)
            v4, b4 = seg(r4)
            assert b1 == b4
            assert v1.shards is None
            assert v4.shards == [ci % 4 for ci in range(len(v4.chunks))]
            # sharded store -> 1-device read; 1-device store -> sharded read
            s1 = RetrievalService(DatasetStore.open(r4)).open_session()
            x1, bd1, f1 = s1.retrieve("v", 1e-3)
            s4 = RetrievalService(DatasetStore.open(r1),
                                  mesh=mesh).open_session()
            x4, bd4, f4 = s4.retrieve("v", 1e-3)
            assert (x1 == x4).all() and bd1 == bd4 and f1 == f4
            assert float(np.abs(x4 - x).max()) <= bd4 <= 1e-3
            # sharded service over the sharded store: batched multi-session
            # serving matches too, incrementally down to a tighter tolerance
            svc = RetrievalService(DatasetStore.open(r4), mesh=mesh)
            sa, sb = svc.open_session(), svc.open_session()
            outs = svc.retrieve_many([(sa, "v", 1e-2), (sb, "v", 1e-3)])
            assert (outs[1][0] == x1).all()
            xt, bdt, _ = sa.retrieve("v", 1e-4)
            assert float(np.abs(xt - x).max()) <= bdt <= 1e-4
        print("OK")
    """, n_devices=4)
