"""Chunked pipeline (Fig 4) round-trips + fp64 (Miranda-dtype) exact codec."""
import numpy as np
import pytest

from repro.ckpt import bitcast_codec as bc
from repro.core.pipeline import ChunkedRefactorPipeline, ChunkedReconstructPipeline
from repro.data.fields import gaussian_field


@pytest.mark.parametrize("pipelined", [False, True])
def test_chunked_pipeline_roundtrip(pipelined):
    x = gaussian_field((48, 48, 48), slope=-2.2, seed=3)
    p = ChunkedRefactorPipeline(chunk_elems=1 << 15, pipelined=pipelined,
                                levels=2)
    blobs = p.refactor(x, "v")
    assert p.stats.chunks == (48 ** 3) // (1 << 15) + (1 if (48**3) % (1 << 15) else 0)
    r = ChunkedReconstructPipeline(pipelined=pipelined)
    xh = r.reconstruct(blobs, tol=1e-4)
    assert np.abs(xh - x.reshape(-1)).max() <= 1e-4


def test_fp64_codec_bit_exact_and_progressive():
    rng = np.random.default_rng(0)
    x = (rng.normal(size=4000) * np.exp2(rng.integers(-40, 40, 4000)))
    assert x.dtype == np.float64
    r = bc.exact_refactor(x)
    full, nb_full = bc.exact_retrieve(r, None)
    assert np.array_equal(full.view(np.uint8), x.view(np.uint8))  # bit exact
    approx, nb_part = bc.exact_retrieve(r, 1e-3)
    rel = np.abs(approx - x) / np.maximum(np.abs(x), 1e-300)
    assert rel.max() <= 1e-3 * 1.01 + 2 ** -20
    assert nb_part < nb_full  # progressive reads fewer bytes


def test_fp64_hi_lo_split_sizes():
    x = np.ones(1000, np.float64)
    r = bc.exact_refactor(x)
    assert r.n_bits == 64 and sum(r.group_planes) == 64
