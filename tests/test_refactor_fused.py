"""Fused one-dispatch write engine: byte-identity with the per-piece oracles
(property-tested over shapes/levels/designs incl. 0-d and empty pieces), the
O(1)-dispatch + O(1)-sync budget contract, stacked lossless entry, and the
dispatch-ahead / stage-timing pipeline semantics."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax

from repro.core import align as al
from repro.core import lossless as ll
from repro.core import lossless_batch as lb
from repro.core import pipeline as pl
from repro.core import refactor as rf
from repro.core import refactor_fused as rff
from repro.core import retrieve as rt
from repro.kernels import ops as kops
from repro.data.fields import gaussian_field

RNG = np.random.default_rng(17)


def _field(shape):
    n = int(np.prod(shape, dtype=int))
    if n == 0:
        return np.zeros(shape, np.float32)
    if n <= 4:
        return RNG.normal(size=shape).astype(np.float32)
    return gaussian_field(shape, slope=-2.0, seed=n % 97)


# ------------------------------------------------------------- byte identity

@pytest.mark.parametrize("shape,design,levels", [
    ((36, 36), "register_block", 2),
    ((33, 47), "locality", 3),
    ((2000,), "shuffle", 2),
    ((), "register_block", 1),          # 0-d
    ((3, 0), "register_block", 2),      # empty
    ((9, 9, 9), "register_block", 1),
])
def test_fused_serialization_identical_to_oracles(shape, design, levels):
    x = _field(shape)
    r_f = rf.refactor_array(x, "t", levels=levels, design=design, fused=True)
    r_b = rf.refactor_array(x, "t", levels=levels, design=design,
                            fused=False, batched=True)
    r_p = rf.refactor_array(x, "t", levels=levels, design=design,
                            batched=False)
    blob = rf.refactored_to_bytes(r_f)
    assert blob == rf.refactored_to_bytes(r_b)
    assert blob == rf.refactored_to_bytes(r_p)
    if x.size:
        xh, bound, _ = rt.ProgressiveReader(r_f).retrieve(1e-4)
        assert np.abs(xh - x).max() <= bound


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.integers(0, 2), st.integers(0, 2 ** 31 - 1),
       st.sampled_from([1, 2, 3]), st.sampled_from([4, 8, 23]))
def test_fused_identity_property(ndim, extra, seed, levels, group_size):
    rng = np.random.default_rng(seed)
    shape = tuple(int(d) for d in rng.integers(1, 40, ndim)) + (1,) * extra
    x = rng.normal(size=shape).astype(np.float32)
    cfg = ll.HybridConfig(group_size=group_size)
    r_f = rf.refactor_array(x, "p", levels=levels, hybrid=cfg, fused=True)
    r_b = rf.refactor_array(x, "p", levels=levels, hybrid=cfg, fused=False,
                            batched=True)
    assert rf.refactored_to_bytes(r_f) == rf.refactored_to_bytes(r_b)


@pytest.mark.parametrize("force", ["huffman", "rle", "dc"])
def test_fused_identical_under_forced_codecs(force):
    x = gaussian_field((40, 40), slope=-2.0, seed=11)
    cfg = ll.HybridConfig(force=force)
    r_f = rf.refactor_array(x, "t", levels=2, hybrid=cfg, fused=True)
    r_b = rf.refactor_array(x, "t", levels=2, hybrid=cfg, fused=False,
                            batched=True)
    assert rf.refactored_to_bytes(r_f) == rf.refactored_to_bytes(r_b)


# ------------------------------------------------------- stacked lossless API

def test_encode_groups_stacked_matches_rowwise():
    import jax.numpy as jnp
    rows_a = (RNG.geometric(0.25, (3, 4096)) % 256).astype(np.uint8)
    rows_b = RNG.integers(0, 256, (2, 512)).astype(np.uint8)
    rows_c = np.zeros((2, 0), np.uint8)  # empty blobs stay host-side
    segs = lb.encode_groups_stacked(
        [jnp.asarray(rows_a), jnp.asarray(rows_b), jnp.asarray(rows_c)])
    flat = [r for rows in (rows_a, rows_b, rows_c) for r in rows]
    assert len(segs) == len(flat)
    for seg, row in zip(segs, flat):
        assert seg.to_bytes() == ll.compress_group(row).to_bytes()


def test_encode_groups_stacked_two_syncs():
    import jax.numpy as jnp
    rows = (RNG.geometric(0.25, (4, 4096)) % 256).astype(np.uint8)
    more = RNG.integers(0, 256, (3, 4096)).astype(np.uint8)  # same size bucket
    lb.STATS.reset()
    lb.encode_groups_stacked([jnp.asarray(rows), jnp.asarray(more)])
    snap = lb.STATS.snapshot()
    assert snap["host_syncs"] == 2
    assert snap["hist_batches"] == 1  # same-size stacks merged into one bucket


# ----------------------------------------------------------- dispatch budget

def _count_calls(monkeypatch, mod, names):
    counts = {n: 0 for n in names}
    for n in names:
        orig = getattr(mod, n)

        def wrapper(*a, _n=n, _orig=orig, **kw):
            counts[_n] += 1
            return _orig(*a, **kw)

        monkeypatch.setattr(mod, n, wrapper)
    return counts


def test_fused_write_O1_dispatches_and_syncs(monkeypatch):
    """One jitted dispatch + three host syncs per chunk on the fused path,
    regardless of pieces x groups; the per-piece oracle's dispatch count
    scales with the piece count."""
    x = gaussian_field((48, 48), slope=-2.0, seed=5)
    # warm the jit/plan caches so trace-time Python calls don't count
    for levels, gs in [(1, 8), (3, 2)]:
        rf.refactor_array(x, "w", levels=levels,
                          hybrid=ll.HybridConfig(group_size=gs), fused=True)

    kcounts = _count_calls(monkeypatch, kops,
                           ["encode_bitplanes", "encode_bitplanes_batch"])
    acounts = _count_calls(monkeypatch, al, ["align_encode"])
    fused_dispatches, fused_syncs = [], []
    for levels, gs in [(1, 8), (3, 2)]:  # 2 pieces x 4 groups vs 4 x 12
        lb.STATS.reset()
        rff.STATS.reset()
        r = rf.refactor_array(x, "w", levels=levels,
                              hybrid=ll.HybridConfig(group_size=gs),
                              fused=True)
        assert len(r.pieces) == levels + 1
        fused_dispatches.append(rff.STATS.snapshot()["dispatches"])
        fused_syncs.append(lb.STATS.snapshot()["host_syncs"])
    # O(1): one fused dispatch and three syncs, independent of decomposition
    assert fused_dispatches == [1, 1]
    assert fused_syncs == [3, 3]
    # warm path never re-enters the per-piece dispatch sites
    assert kcounts["encode_bitplanes"] == 0
    assert kcounts["encode_bitplanes_batch"] == 0
    assert acounts["align_encode"] == 0

    # per-piece oracle: 2 encode dispatches + 1 align dispatch per piece
    r = rf.refactor_array(x, "w", levels=3, fused=False, batched=True)
    assert kcounts["encode_bitplanes"] == 2 * len(r.pieces)
    assert acounts["align_encode"] == len(r.pieces)


def test_fused_requires_batched():
    with pytest.raises(ValueError, match="fused=True requires batched=True"):
        rf.refactor_array(np.ones((8,), np.float32), batched=False, fused=True)


def test_fused_is_default_and_plan_cache_reused():
    x = gaussian_field((32, 32), slope=-2.0, seed=3)
    rff.STATS.reset()
    rf.refactor_array(x, "a", levels=2)
    builds_first = rff.STATS.snapshot()["plan_builds"]
    rf.refactor_array(x * 2, "b", levels=2)
    snap = rff.STATS.snapshot()
    assert snap["dispatches"] == 2          # fused is the default path
    assert snap["plan_builds"] == builds_first  # second chunk reuses the plan


# ------------------------------------------------- pipeline dispatch-ahead

def test_pipelined_copy_in_never_blocks(monkeypatch):
    """The pipelined write path must not pay a per-chunk H2D sync; serial
    mode keeps the barrier for the stage-timing contract."""
    calls = []
    orig = pl._sync_stage
    monkeypatch.setattr(pl, "_sync_stage",
                        lambda dev: (calls.append(1), orig(dev))[1])
    x = gaussian_field((64, 64, 4), slope=-2.0, seed=8)
    p = pl.ChunkedRefactorPipeline(chunk_elems=1 << 13, pipelined=True,
                                   levels=2)
    assert p.stage_timing is False
    blobs = p.refactor(x, "v")
    assert calls == []
    s = pl.ChunkedRefactorPipeline(chunk_elems=1 << 13, pipelined=False,
                                   levels=2)
    assert s.stage_timing is True
    blobs_serial = s.refactor(x, "v")
    assert len(calls) >= s.stats.chunks  # serial mode synced every copy-in
    assert blobs == blobs_serial


@pytest.mark.parametrize("dispatch_ahead", [1, 2, 3])
def test_dispatch_ahead_preserves_order_and_bytes(dispatch_ahead):
    x = gaussian_field((64, 64, 4), slope=-2.0, seed=8)
    base = pl.ChunkedRefactorPipeline(chunk_elems=1 << 13, pipelined=False,
                                      levels=2).refactor(x, "v")
    p = pl.ChunkedRefactorPipeline(chunk_elems=1 << 13, pipelined=True,
                                   levels=2, dispatch_ahead=dispatch_ahead)
    assert p.refactor(x, "v") == base


def test_dispatch_ahead_sink_exception_propagates():
    x = gaussian_field((32, 32, 4), slope=-2.0, seed=8)

    def sink(ci, refd):
        if ci == 2:
            raise RuntimeError("sink boom")
        return b""

    p = pl.ChunkedRefactorPipeline(chunk_elems=1 << 10, pipelined=True,
                                   levels=1, sink=sink, dispatch_ahead=3)
    with pytest.raises(RuntimeError, match="sink boom"):
        p.refactor(x, "v")


def test_writer_fused_store_roundtrip(tmp_path):
    from repro.store import DatasetStore, DatasetWriter, RetrievalService
    x = gaussian_field((24, 24, 24), slope=-2.0, seed=9)
    root_f, root_o = str(tmp_path / "fused"), str(tmp_path / "oracle")
    with DatasetWriter(root_f, chunk_elems=8000) as w:
        w.write("v", x)
    with DatasetWriter(root_o, chunk_elems=8000, fused=False) as w:
        w.write("v", x)
    # identical segment payload bytes on disk, modulo the generation token
    seg_f = [p for p in (tmp_path / "fused").rglob("*.seg")]
    seg_o = [p for p in (tmp_path / "oracle").rglob("*.seg")]
    assert seg_f and seg_o  # layout names segment files <var>-<gen>.seg
    assert seg_f[0].read_bytes() == seg_o[0].read_bytes()
    svc = RetrievalService(DatasetStore.open(root_f), depth=3)
    s = svc.open_session()
    xh, bound, fetched = s.retrieve("v", 1e-4)
    assert float(np.abs(xh - x).max()) <= bound <= 1e-4
    assert fetched > 0
