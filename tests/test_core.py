"""Core HP-MDR numerics: alignment, decomposition, lossless, refactoring."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import align as al
from repro.core import decompose as dc
from repro.core import lossless as ll
from repro.data.fields import gaussian_field


# ------------------------------------------------------------------- align --

@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, al.DEFAULT_MAG_BITS))
def test_align_truncation_bound(seed, planes):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=512) * 10.0 ** float(rng.integers(-6, 6))).astype(np.float32)
    mag, sign, e = al.align_encode(jnp.asarray(x))
    tail = al.DEFAULT_MAG_BITS - planes
    mag_t = (np.asarray(mag) >> tail) << tail if tail else np.asarray(mag)
    xh = al.align_decode(jnp.asarray(mag_t), sign, e, planes_kept=planes)
    bound = al.truncation_error(int(e), planes)
    assert float(np.abs(np.asarray(xh) - x).max()) <= bound * (1 + 1e-6)


def test_align_zero_array():
    mag, sign, e = al.align_encode(jnp.zeros(64))
    assert int(jnp.sum(mag)) == 0
    x = al.align_decode(mag, sign, e)
    assert float(jnp.abs(x).max()) <= al.truncation_error(int(e), 30)


# --------------------------------------------------------------- decompose --

@pytest.mark.parametrize("shape", [(64,), (33, 47), (16, 20, 24)])
def test_decompose_invertible(shape):
    x = gaussian_field(shape, seed=1)
    lv = dc.num_levels(shape, min_size=4, max_levels=3)
    pieces = dc.decompose(jnp.asarray(x), lv)
    assert sum(int(np.prod(p.shape)) for p in pieces) == x.size
    xr = np.asarray(dc.recompose(pieces, shape, lv))
    assert np.abs(xr - x).max() < 8 * 2 ** -24 * np.abs(x).max() * lv * len(shape)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_decompose_error_bound_property(seed):
    """Quantizing the pieces keeps reconstruction within the advertised bound."""
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(9, 24, size=rng.integers(1, 3)))
    x = gaussian_field(shape, slope=float(rng.uniform(-3, -1)), seed=seed)
    lv = dc.num_levels(shape, min_size=4, max_levels=3)
    pieces = dc.decompose(jnp.asarray(x), lv)
    eps = []
    noisy = []
    for p in pieces:
        e = float(10.0 ** rng.uniform(-6, -2))
        eps.append(e)
        noise = rng.uniform(-e, e, size=p.shape).astype(np.float32)
        noisy.append(p + noise)
    bound = dc.error_bound(eps, ndim=len(shape), data_amax=float(np.abs(x).max()))
    xr = np.asarray(dc.recompose(noisy, shape, lv))
    assert np.abs(xr - x).max() <= bound * (1 + 1e-5)


# ---------------------------------------------------------------- lossless --

CASES = {
    "skewed": lambda rng: (rng.geometric(0.25, 30000) % 256).astype(np.uint8),
    "zeros": lambda rng: np.zeros(40000, np.uint8),
    "uniform": lambda rng: rng.integers(0, 256, 30000).astype(np.uint8),
    "runs": lambda rng: np.repeat(rng.integers(0, 5, 60),
                                  rng.integers(1, 3000, 60)).astype(np.uint8),
    "tiny": lambda rng: rng.integers(0, 256, 3).astype(np.uint8),
    "empty": lambda rng: np.zeros(0, np.uint8),
}


@pytest.mark.parametrize("case", list(CASES))
@pytest.mark.parametrize("codec", ["huffman", "rle", "dc", "hybrid"])
def test_lossless_roundtrip(case, codec):
    data = CASES[case](np.random.default_rng(1))
    if codec == "hybrid":
        seg = ll.compress_group(data)
    else:
        seg = {"huffman": ll.huffman_encode, "rle": ll.rle_encode,
               "dc": ll.dc_encode}[codec](data)
    seg2 = ll.Segment.from_bytes(seg.to_bytes())
    out = ll.decompress_group(seg2)
    assert np.array_equal(out, data), (case, codec)


@settings(max_examples=25, deadline=None)
@given(st.binary(min_size=0, max_size=20000), st.sampled_from(["huffman", "rle"]))
def test_lossless_roundtrip_property(blob, codec):
    data = np.frombuffer(blob, dtype=np.uint8)
    enc = ll.huffman_encode if codec == "huffman" else ll.rle_encode
    dec = ll.huffman_decode if codec == "huffman" else ll.rle_decode
    assert np.array_equal(dec(enc(data)), data)


def test_huffman_estimate_close_to_actual():
    rng = np.random.default_rng(2)
    data = (rng.geometric(0.3, 50000) % 256).astype(np.uint8)
    hist = np.bincount(data, minlength=256)
    cr_est, lengths, codes = ll.estimate_huffman(hist, data.size)
    seg = ll.huffman_encode(data, hist=hist, codebook=(lengths, codes))
    cr_act = data.size / seg.stored_bytes
    assert abs(cr_est - cr_act) / cr_act < 0.25


def test_algorithm2_selection_logic():
    rng = np.random.default_rng(3)
    cfg = ll.HybridConfig(size_threshold=4096, cr_threshold=1.0)
    small = rng.integers(0, 2, 100).astype(np.uint8)
    assert ll.compress_group(small, cfg).method == "dc"         # S <= T_s
    compressible = np.zeros(50000, np.uint8)
    assert ll.compress_group(compressible, cfg).method == "huffman"
    incompressible = rng.integers(0, 256, 50000).astype(np.uint8)
    assert ll.compress_group(incompressible, cfg).method == "dc"
