"""Multi-device tests (subprocess with host devices): sharded train step,
seq-sharded flash decode, compressed allreduce wire-savings, elastic restore,
mini dry-run of the production machinery at 8 devices."""
import pytest


def test_sharded_train_step_runs(subproc):
    out = subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs.base import smoke_config, ShapeConfig, input_specs
        from repro.distributed import sharding as shd
        from repro.launch.policy import cell_policy
        from repro.models.model import Model
        from repro.optim import adamw
        from repro.train import step as steps

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = smoke_config("deepseek-67b")
        shape = ShapeConfig("t", 32, 8, "train")
        with shd.use_mesh(mesh):
            policy = cell_policy(cfg, shape, mesh)
            model = Model(cfg)
            params = jax.device_put(model.init(jax.random.PRNGKey(0)),
                jax.tree.map(lambda s: NamedSharding(mesh, s), model.partition_specs()))
            opt_cfg = adamw.AdamWConfig()
            opt = adamw.init(params, opt_cfg)
            fn = jax.jit(steps.make_train_step(model, opt_cfg, policy))
            batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
                     "labels": jnp.zeros((8, 32), jnp.int32)}
            p2, o2, metrics = fn(params, opt, batch)
            print("LOSS", float(metrics["loss"]))
        """)
    assert "LOSS" in out


def test_compressed_allreduce_saves_wire(subproc):
    out = subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.grad_compress import make_compressed_allreduce
        from repro.launch.hlo_analysis import HloAnalysis
        mesh = jax.make_mesh((8,), ("data",))
        n = 1 << 20

        def plain(x):
            return jnp.mean(x, axis=0)
        xs = jax.ShapeDtypeStruct((8, n), jnp.float32)
        with mesh:
            sh = NamedSharding(mesh, P("data", None))
            c_plain = jax.jit(plain, in_shardings=(sh,),
                              out_shardings=NamedSharding(mesh, P())).lower(xs).compile()
            f = make_compressed_allreduce(mesh, "data", planes=6)
            c_comp = jax.jit(f, in_shardings=(sh,)).lower(xs).compile()
        wp = HloAnalysis(c_plain.as_text()).summary()["collective_wire_bytes_per_device"]
        wc = HloAnalysis(c_comp.as_text()).summary()["collective_wire_bytes_per_device"]
        print("PLAIN", wp, "COMP", wc)
        # correctness
        with mesh:
            x = jax.device_put(np.random.default_rng(0).normal(size=(8, n)).astype(np.float32), sh)
            out, _ = jax.jit(f)(x)
        err = np.abs(np.asarray(out)[0] - np.asarray(x).mean(0)).max()
        rng_scale = np.abs(np.asarray(x).mean(0)).max()
        print("ERR", err / rng_scale)
        assert err / rng_scale < 2**-6
        """)
    vals = {k: float(v) for k, v in zip(
        ["PLAIN", "COMP"], out.split("PLAIN ")[1].split("ERR")[0]
        .replace("COMP", "").split())}
    # compressed all-gather phase must move far fewer bytes than a plain
    # all-reduce (sign+6 planes of 31 bits + rs phase ~= 55% of 2x full)
    assert vals["COMP"] < 0.62 * vals["PLAIN"], vals


def test_elastic_restore_across_meshes(subproc):
    out = subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs.base import smoke_config
        from repro.distributed import sharding as shd
        from repro.models.model import Model
        from repro.ckpt import manager as ck

        cfg = smoke_config("qwen2-7b")
        model = Model(cfg)
        # save under an 8-device (4,2) mesh
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        with shd.use_mesh(mesh_a):
            sh_a = jax.tree.map(lambda s: NamedSharding(mesh_a, s),
                                model.partition_specs())
            params = jax.device_put(model.init(jax.random.PRNGKey(0)), sh_a)
            ck.save("/tmp/elastic_ck", 1, params)
        # 'lose half the nodes': restore onto a 4-device (2,2) mesh
        devs = np.array(jax.devices()[:4]).reshape(2, 2)
        mesh_b = jax.sharding.Mesh(devs, ("data", "model"))
        with shd.use_mesh(mesh_b):
            sh_b = jax.tree.map(lambda s: NamedSharding(mesh_b, s),
                                model.partition_specs())
            restored, _ = ck.load("/tmp/elastic_ck", 1, model.shape_structs(),
                                  shardings=sh_b)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        print("ELASTIC_OK", len(jax.tree.leaves(restored)))
        """)
    assert "ELASTIC_OK" in out


def test_mini_dryrun_all_step_kinds(subproc):
    """The full dry-run machinery at 8-device scale on two archs."""
    out = subproc("""
        import dataclasses, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding
        from repro.configs.base import smoke_config, input_specs, ShapeConfig
        from repro.distributed import sharding as shd
        from repro.launch.policy import cell_policy
        from repro.launch.hlo_analysis import HloAnalysis
        from repro.models.model import Model
        from repro.optim import adamw
        from repro.train import step as steps

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        for arch in ["jamba-v0.1-52b", "deepseek-v2-236b"]:
            cfg = smoke_config(arch)
            for kind, b, s in [("train", 8, 32), ("prefill", 4, 64),
                               ("decode", 8, 64)]:
                shape = ShapeConfig(kind, s, b, kind)
                with shd.use_mesh(mesh):
                    policy = cell_policy(cfg, shape, mesh)
                    model = Model(cfg)
                    pshape = model.shape_structs()
                    pshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp),
                                          model.partition_specs())
                    bspecs = input_specs(cfg, shape)
                    bshard = steps.batch_shardings(bspecs, policy, mesh)
                    if kind == "train":
                        oc = adamw.AdamWConfig()
                        osh = jax.eval_shape(lambda p: adamw.init(p, oc), pshape)
                        ospecs = adamw.state_partition_specs(model.partition_specs())
                        oshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), ospecs,
                            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
                        fn = steps.make_train_step(model, oc, policy)
                        c = jax.jit(fn, in_shardings=(pshard, oshard, bshard),
                                    out_shardings=(pshard, oshard, None)).lower(
                            pshape, osh, bspecs).compile()
                    elif kind == "prefill":
                        fn = steps.make_prefill_step(model)
                        c = jax.jit(fn, in_shardings=(pshard, bshard)).lower(
                            pshape, bspecs).compile()
                    else:
                        cfg2 = dataclasses.replace(
                            cfg, seq_shard_decode=policy.seq_shard,
                            decode_batch_axes=tuple(policy.batch_axes))
                        model2 = Model(cfg2)
                        cache = model2.init_cache_structs(b, policy.cache_len)
                        cshard = steps.cache_shardings(cache, policy, mesh)
                        fn = steps.make_decode_step(model2)
                        c = jax.jit(fn, in_shardings=(pshard, cshard, None, bshard),
                                    out_shardings=(None, cshard)).lower(
                            pshape, cache, jax.ShapeDtypeStruct((), jnp.int32),
                            bspecs).compile()
                    a = HloAnalysis(c.as_text()).summary()
                    assert a["flops_per_device"] > 0, (arch, kind)
                    print("MINI_OK", arch, kind)
        """)
    assert out.count("MINI_OK") == 6
