"""Per-architecture smoke tests (reduced configs): forward/train step on CPU,
output shapes + finite values; MoE dispatch vs oracle; SSM scan-vs-step;
prefill+decode consistency with full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import list_archs, smoke_config, get_config, SHAPES, cell_supported
from repro.models.model import Model, count_params
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.params import tree_init

ARCHS = list_archs()
B, S = 2, 24


def _batch(cfg, key=1):
    rng = jax.random.PRNGKey(key)
    batch = {"labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.external_embed:
        batch["embeds"] = jax.random.normal(rng, (B, S, cfg.d_model), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    if cfg.cross_attn_period:
        batch["vision_states"] = jax.random.normal(
            rng, (B, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(lambda p: m.loss(p, batch)))(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    logits = m.forward(params, tokens=batch.get("tokens"),
                       embeds=batch.get("embeds"),
                       vision_states=batch.get("vision_states"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not smoke_config(a).encoder_only])
def test_prefill_decode_matches_forward(arch):
    """decode(prefill(x[:-1]), x[-1]) logits == forward(x) at the last pos."""
    cfg = smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    toks = batch["tokens"]
    vis = batch.get("vision_states")
    full = m.forward(params, tokens=toks, vision_states=vis)
    logits_p, caches = jax.jit(
        lambda p, t: m.prefill(p, tokens=t[:, :-1], vision_states=vis,
                               max_len=S + 4))(params, toks)
    out, _ = jax.jit(
        lambda p, c, t: m.decode_step(p, c, jnp.int32(S - 1), t,
                                      vision_states=vis))(
        params, caches, toks[:, -1:])
    a = np.asarray(full[:, -1])
    b = np.asarray(out[:, 0])
    np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2)


def test_param_counts_match_advertised():
    expected = {"rwkv6-3b": 3.1, "deepseek-67b": 67.4, "h2o-danube-3-4b": 4.0,
                "command-r-plus-104b": 103.8, "qwen2-7b": 7.6,
                "hubert-xlarge": 1.26, "jamba-v0.1-52b": 51.6,
                "deepseek-v2-236b": 235.7, "deepseek-v3-671b": 671.7,
                "llama-3.2-vision-90b": 87.7}
    for arch, exp in expected.items():
        n = count_params(get_config(arch)) / 1e9
        assert abs(n - exp) / exp < 0.02, (arch, n, exp)
    # MoE active counts
    assert abs(count_params(get_config("deepseek-v3-671b"), active_only=True)
               / 1e9 - 38.2) < 1.5
    assert abs(count_params(get_config("deepseek-v2-236b"), active_only=True)
               / 1e9 - 21.4) < 1.5


def test_moe_dispatch_matches_oracle():
    from repro.configs.base import ModelConfig, MoEConfig
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                      moe=MoEConfig(n_experts=8, top_k=2, d_expert=32,
                                    n_shared=1, capacity_factor=8.0),
                      compute_dtype="float32")
    p = tree_init(MOE.moe_abstract(cfg), jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    out, aux = MOE.moe_apply(p, x, cfg)
    ref = MOE.moe_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_gracefully():
    from repro.configs.base import ModelConfig, MoEConfig
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                      moe=MoEConfig(n_experts=4, top_k=2, d_expert=16,
                                    capacity_factor=0.25),
                      compute_dtype="float32")
    p = tree_init(MOE.moe_abstract(cfg), jax.random.PRNGKey(0), "float32")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    out, _ = MOE.moe_apply(p, x, cfg)
    assert bool(jnp.isfinite(out).all())


@pytest.mark.parametrize("kind", ["rwkv6", "mamba"])
def test_ssm_scan_equals_stepwise(kind):
    from repro.configs.base import ModelConfig, SSMConfig
    d = 128
    cfg = ModelConfig(name="t", family="ssm", n_layers=1, d_model=d,
                      n_heads=2, n_kv_heads=2, d_ff=2 * d, vocab_size=64,
                      ssm=SSMConfig(kind=kind, d_state=8, expand=2, dt_rank=8),
                      compute_dtype="float32")
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 10, d)) * 0.1
    if kind == "rwkv6":
        p = tree_init(SSM.rwkv_time_mix_abstract(cfg), jax.random.PRNGKey(2),
                      "float32")
        y_full, _ = SSM.rwkv_time_mix_apply(p, x, cfg)
        st = {"shift": jnp.zeros((2, d)),
              "wkv": jnp.zeros((2, d // 64, 64, 64))}
        step = SSM.rwkv_time_mix_apply
    else:
        p = tree_init(SSM.mamba_abstract(cfg), jax.random.PRNGKey(2), "float32")
        y_full, _ = SSM.mamba_apply(p, x, cfg)
        st = {"conv": jnp.zeros((2, 3, 2 * d)), "ssm": jnp.zeros((2, 2 * d, 8))}
        step = SSM.mamba_apply
    ys = []
    for t in range(10):
        yt, st = step(p, x[:, t:t + 1], cfg, state=st)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, axis=1)),
                               atol=1e-4)


def test_cell_skip_rules():
    skips = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for s in SHAPES.values():
            ok, _ = cell_supported(cfg, s)
            skips += not ok
    assert skips == 8  # DESIGN.md §7: exactly 8 skipped cells


def test_int8_kv_cache_decode_quality():
    """HP-MDR exponent-aligned int8 KV cache: top-1 decode agreement with the
    bf16 cache (worst case: random-init weights)."""
    cfg0 = smoke_config("deepseek-67b")
    m0 = Model(cfg0)
    params = m0.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg0.vocab_size)
    _, caches = jax.jit(lambda p, t: m0.prefill(p, t, max_len=32))(params, toks)
    out0, _ = jax.jit(lambda p, c, t: m0.decode_step(
        p, c, jnp.int32(16), t))(params, caches, toks[:, -1:])
    cfg1 = dataclasses.replace(cfg0, kv_cache_int8_scale=8.0)
    m1 = Model(cfg1)
    _, caches1 = jax.jit(lambda p, t: m1.prefill(p, t, max_len=32))(params, toks)
    assert jax.tree.leaves(caches1)[0].dtype in (jnp.int8, jnp.bfloat16)
    out1, _ = jax.jit(lambda p, c, t: m1.decode_step(
        p, c, jnp.int32(16), t))(params, caches1, toks[:, -1:])
    rel = float(jnp.abs(out1 - out0).max()) / float(jnp.abs(out0).max())
    agree = float(jnp.mean(jnp.argmax(out1, -1) == jnp.argmax(out0, -1)))
    assert rel < 0.1 and agree == 1.0
