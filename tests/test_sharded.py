"""Mesh-sharded refactor & retrieval (core.sharded): a mesh of one device is
byte-identical to today's single-device path (property-tested), multi-device
runs produce bit-identical serialized output to the single-device oracle
(subprocess with 4 host devices), the shard_map kernel wrappers match their
unsharded twins bitwise, and the manifest shard field round-trips."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import lossless_batch as lb
from repro.core import pipeline as pl
from repro.core import refactor as rf
from repro.core import refactor_fused as rff
from repro.core import sharded as shd
from repro.data.fields import gaussian_field
from repro.store import layout as lo

RNG = np.random.default_rng(23)


def _field(n):
    if n == 0:
        return np.zeros(0, np.float32)
    if n <= 4:
        return RNG.normal(size=n).astype(np.float32)
    return gaussian_field((n,), slope=-2.0, seed=n % 89)


# ------------------------------------------------- mesh-of-one == today's path

@settings(max_examples=8, deadline=None)
@given(st.sampled_from([0, 1, 7, 1000, 4097]), st.sampled_from([1, 2, 3]))
def test_mesh_of_one_refactor_byte_identity(n, levels):
    """Property: a 1-device mesh serializes byte-identically to the fused
    single-device engine, including empty and tiny chunks."""
    x = _field(n)
    plan = shd.ShardedRefactorPlan(shd.make_chunk_mesh(1), levels=levels)
    [sharded] = plan.refactor_chunks([x], name="v")
    oracle = rff.refactor_fused(x, name="v.0", levels=levels)
    assert rf.refactored_to_bytes(sharded) == rf.refactored_to_bytes(oracle)


def test_mesh_of_one_pipeline_roundtrip_byte_identity():
    x = gaussian_field((6000,), slope=-2.0, seed=3)
    mesh = shd.make_chunk_mesh(1)
    blobs0 = pl.ChunkedRefactorPipeline(chunk_elems=2048, levels=2).refactor(x)
    blobs1 = pl.ChunkedRefactorPipeline(chunk_elems=2048, levels=2,
                                        mesh=mesh).refactor(x)
    assert blobs0 == blobs1
    y0 = pl.ChunkedReconstructPipeline().reconstruct(blobs0, 1e-4)
    y1 = pl.ChunkedReconstructPipeline(mesh=mesh).reconstruct(blobs1, 1e-4)
    assert (y0 == y1).all()
    assert np.abs(y1 - x).max() <= 1e-4


def test_batched_finish_costs_three_syncs_total():
    """finish_many resolves a whole batch in 3 host syncs flat — one scalar
    gather + the stacked codec engine's stats/payload pair — vs 3 PER CHUNK
    individually, so the amortized gather count per chunk is 1/batch."""
    chunks = [_field(2048), _field(2048)]
    plan = shd.ShardedRefactorPlan(shd.make_chunk_mesh(1), levels=2)
    pend = plan.dispatch_round(list(enumerate(chunks)), name="v")
    before = lb.STATS.snapshot()["host_syncs"]
    outs = plan.finish_many(pend)
    assert lb.STATS.snapshot()["host_syncs"] - before == 3
    # and byte-identical to the per-chunk fused oracle
    for i, (x, refd) in enumerate(zip(chunks, outs)):
        oracle = rff.refactor_fused(x, name=f"v.{i}", levels=2)
        assert rf.refactored_to_bytes(refd) == rf.refactored_to_bytes(oracle)


# ------------------------------------------------------------- mesh plumbing

def test_resolve_mesh_validation():
    assert shd.resolve_mesh(None) is None
    m = shd.resolve_mesh(1)
    assert shd.resolve_mesh(m) is m
    assert shd.chunk_devices(None) == [None]
    assert len(shd.chunk_devices(m)) == 1
    with pytest.raises(ValueError, match="only"):
        shd.resolve_mesh(4096)
    with pytest.raises(ValueError, match=">= 1"):
        shd.make_chunk_mesh(0)
    with pytest.raises(TypeError, match="mesh must be"):
        shd.resolve_mesh("chunk")


def test_shard_for_uses_manifest_map_modulo_mesh():
    eng = shd.ShardedReconstructEngine(shd.make_chunk_mesh(1),
                                       shards=[3, 1, 2])
    # recorded shards are taken modulo the (here: smaller) mesh size, and
    # chunks beyond the recorded map fall back to round-robin
    assert [eng.shard_for(ci) for ci in range(4)] == [0, 0, 0, 0]
    eng2 = shd.ShardedReconstructEngine(None)
    assert eng2.device_for(5) is None


def test_manifest_shards_field_roundtrip():
    v = lo.VariableEntry(name="v", shape=(8,), levels=1, design="register_block",
                         mag_bits=23, group_size=8, chunk_elems=8,
                         segment_file="segments/v.seg", amax=1.0, range=2.0,
                         chunks=[], shards=[0, 1, 0, 1])
    j = v.to_json()
    assert j["shards"] == [0, 1, 0, 1]
    assert lo.VariableEntry.from_json(j).shards == [0, 1, 0, 1]
    # absent field (pre-sharding manifests) => single-device
    j.pop("shards")
    assert lo.VariableEntry.from_json(j).shards is None


# --------------------------------------------- multi-device (subprocess) tests

def test_multi_device_write_oracle(subproc):
    """Acceptance: with 4 host devices, the sharded pipeline's serialized
    chunks are byte-identical to the single-device writer's, dispatches are
    round-robin, and a 2-device mesh agrees too."""
    subproc("""
        import numpy as np, jax
        assert len(jax.devices()) == 4
        from repro.core import lossless_batch as lb
        from repro.core import pipeline as pl, sharded as shd
        x = np.random.default_rng(11).standard_normal(32768).astype(np.float32)
        base = pl.ChunkedRefactorPipeline(chunk_elems=4096, levels=2).refactor(x)
        for n in (1, 2, 4):
            shd.STATS.reset()
            lb.STATS.reset()
            mesh = shd.make_chunk_mesh(n)
            blobs = pl.ChunkedRefactorPipeline(chunk_elems=4096, levels=2,
                                               dispatch_ahead=2,
                                               mesh=mesh).refactor(x)
            assert blobs == base, f"{n}-device output differs from oracle"
            hist = shd.STATS.snapshot()["dispatches_by_device"]
            assert hist == {k: 8 // n for k in range(n)}  # flat round-robin
            # async window-batched finish: 8 chunks drain in full windows of
            # dispatch_ahead(=2) * n chunks, 3 host syncs per drain (scalar
            # gather + codec stats + codec payload) — amortized WELL below
            # the 3-per-chunk serial budget
            st = shd.STATS.snapshot()
            drains = -(-8 // (2 * n))  # ceil
            assert st["rounds"] == drains and st["chunks_finished"] == 8
            syncs = lb.STATS.snapshot()["host_syncs"]
            assert syncs == 3 * drains, (n, syncs)
        print("OK")
    """, n_devices=4)


def test_multi_device_reconstruct_bit_identical(subproc):
    """Sharded reconstruction (engine state on 4 devices, per-device delta
    decode) is bit-identical to the single-device incremental engine AND to
    the from-scratch oracle readers."""
    subproc("""
        import numpy as np, jax
        from repro.core import pipeline as pl, sharded as shd
        x = np.random.default_rng(5).standard_normal(40000).astype(np.float32)
        blobs = pl.ChunkedRefactorPipeline(chunk_elems=4096, levels=2).refactor(x)
        for tol in (1e-2, 1e-4):
            y1 = pl.ChunkedReconstructPipeline().reconstruct(blobs, tol)
            y4 = pl.ChunkedReconstructPipeline(
                mesh=shd.make_chunk_mesh(4)).reconstruct(blobs, tol)
            yo = pl.ChunkedReconstructPipeline(
                incremental=False).reconstruct(blobs, tol)
            assert (y1 == y4).all() and (y1 == yo).all()
            assert np.abs(y4 - x).max() <= tol
        print("OK")
    """, n_devices=4)


def test_shard_map_wrappers_bitwise(subproc):
    """kops.encode/decode_bitplanes_sharded == their unsharded batch twins,
    bit for bit, under a 4-device 'chunk' mesh."""
    subproc("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import sharded as shd
        from repro.kernels import ops as kops
        mesh = shd.make_chunk_mesh(4)
        mags = jnp.asarray(np.random.default_rng(0).integers(
            0, 2**23, (8, 4096)).astype(np.uint32))
        a = kops.encode_bitplanes_batch(mags, 23)
        b = kops.encode_bitplanes_sharded(mags, 23, mesh=mesh)
        assert a.shape == b.shape and bool((a == b).all())
        d1 = kops.decode_bitplanes_batch(a[:, :8], 23, 4096)
        d2 = kops.decode_bitplanes_sharded(a[:, :8], 23, 4096, mesh=mesh)
        assert bool((d1 == d2).all())
        try:
            kops.encode_bitplanes_sharded(mags[:6], 23, mesh=mesh)
        except ValueError:
            pass
        else:
            raise AssertionError("non-divisible batch must raise")
        print("OK")
    """, n_devices=4)
