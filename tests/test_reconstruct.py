"""Device-resident incremental reconstruction engine: bit-exactness with the
full-decode oracle, level-reuse recompose, O(1)-sync device-resident read
path, and cross-reader batched delta decode."""
import numpy as np
import jax
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import lossless as ll
from repro.core import qoi as qq
from repro.core import reconstruct as rcn
from repro.core import refactor as rf
from repro.core import retrieve as rt
from repro.data.fields import gaussian_field

RNG = np.random.default_rng(7)


def _pair(ref, **kw):
    """(incremental, oracle) readers over the same Refactored."""
    return (rt.ProgressiveReader(ref, incremental=True, **kw),
            rt.ProgressiveReader(ref, incremental=False, **kw))


def _assert_locked(inc, orc):
    xi, bi = inc.reconstruct()
    xo, bo = orc.reconstruct()
    assert bi == bo
    assert xi.dtype == xo.dtype and xi.shape == xo.shape
    assert np.array_equal(xi, xo, equal_nan=True)


# ------------------------------------------------------------- bit-exactness

@pytest.mark.parametrize("shape,design,levels", [
    ((36, 36), "register_block", 2),
    ((33, 47), "locality", 3),
    ((2000,), "register_block", 2),
    ((7, 9, 11), "register_block", 1),
    ((), "register_block", 1),          # 0-d: single corner coefficient
    ((3, 0), "register_block", 2),      # empty: every piece has n == 0
    ((1,), "register_block", 1),        # 1 element: empty detail pieces
])
def test_incremental_bit_exact_over_schedule(shape, design, levels):
    n = int(np.prod(shape, dtype=int))
    x = (gaussian_field(shape, seed=3) if n > 4 else
         RNG.normal(size=shape).astype(np.float32) if n else
         np.zeros(shape, np.float32))
    ref = rf.refactor_array(x, "t", levels=levels, design=design)
    inc, orc = _pair(ref)
    _assert_locked(inc, orc)  # pre-fetch: both reconstruct to zeros
    for tol in [1e-1, 1e-3, 1e-5, 0.0]:  # 0.0 drives to the floor
        fi = inc._fetch_to(inc.plan(max(tol, inc.floor_bound())))
        fo = orc._fetch_to(orc.plan(max(tol, orc.floor_bound())))
        assert fi == fo
        _assert_locked(inc, orc)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_incremental_bit_exact_property(seed):
    """Random shape/levels/design/schedule: the engine's delta decode +
    suffix recompose is bit-identical to the from-scratch oracle after every
    step, including single-group (MA) augmentation steps."""
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(5, 28, size=rng.integers(1, 4)))
    design = ["register_block", "locality"][int(rng.integers(2))]
    levels = int(rng.integers(1, 4))
    x = gaussian_field(shape, slope=float(rng.uniform(-3, -1)), seed=seed)
    ref = rf.refactor_array(x, "p", levels=levels, design=design,
                            hybrid=ll.HybridConfig(group_size=int(rng.integers(2, 9))))
    inc, orc = _pair(ref)
    for tol in sorted(10.0 ** rng.uniform(-6, -1, size=3))[::-1]:
        inc.retrieve(float(tol))
        orc.retrieve(float(tol))
        _assert_locked(inc, orc)
    for _ in range(2):  # finest augmentation granularity
        inc.fetch_one_more_group()
        orc.fetch_one_more_group()
        _assert_locked(inc, orc)


def test_incremental_reconstruct_idempotent():
    """A clean engine serves the cached array (same object, no recompute)."""
    x = gaussian_field((40, 40), seed=5)
    r = rt.ProgressiveReader(rf.refactor_array(x, "t", levels=2))
    r.retrieve(1e-3)
    x1, _ = r.reconstruct_device()
    before = rcn.STATS.snapshot()
    x2, _ = r.reconstruct_device()
    after = rcn.STATS.snapshot()
    assert x2 is x1
    assert after["cache_hits"] == before["cache_hits"] + 1
    assert after["recompose_calls"] == before["recompose_calls"]


def test_level_reuse_on_fine_detail_refinement():
    """Refining only the finest detail piece re-runs only the last recompose
    stage; the coarser level intermediates are served from the cache."""
    x = gaussian_field((64, 64), seed=6)
    ref = rf.refactor_array(x, "t", levels=3)
    inc, orc = _pair(ref)
    inc.retrieve(1e-2)
    orc.retrieve(1e-2)
    finest = len(ref.pieces) - 1
    target = [s.groups_fetched for s in inc.state]
    target[finest] += 1
    before = rcn.STATS.snapshot()
    inc._fetch_to(target)
    inc.reconstruct_device()
    after = rcn.STATS.snapshot()
    assert after["levels_merged"] - before["levels_merged"] == 1
    assert after["levels_reused"] - before["levels_reused"] == ref.levels - 1
    orc._fetch_to(target)
    _assert_locked(inc, orc)


# --------------------------------------------------------------- sync budget

def test_read_path_O1_host_syncs(monkeypatch):
    """The incremental read path performs exactly ONE host sync per fetch
    step (the batched lossless payload sync) regardless of how many (piece,
    group) deltas the step pulls, never invokes the per-group codec decoders,
    and keeps the reconstruction on device (mirrors the write-path test in
    tests/test_lossless_batch.py)."""
    from repro.core import lossless_batch as lb

    def forbid(*a, **kw):
        raise AssertionError("per-group codec invoked on the batched path")

    monkeypatch.setattr(ll, "decompress_group", forbid)
    monkeypatch.setattr(ll, "huffman_decode", forbid)
    monkeypatch.setattr(ll, "rle_decode", forbid)
    monkeypatch.setattr(ll, "dc_decode", forbid)

    x = gaussian_field((48, 48), slope=-2.0, seed=8)
    # force=huffman: every segment goes through the vmapped unpack batch, so
    # each fetch step costs exactly its single payload sync
    r = rt.ProgressiveReader(rf.refactor_array(
        x, "t", levels=3, hybrid=ll.HybridConfig(force="huffman")))
    lb.STATS.reset()
    for step, tol in enumerate([1e-2, 1e-4, 1e-6]):
        r.retrieve_device(tol)
        # one decode_segments payload sync per step, independent of the
        # number of segments the plan fetched
        assert lb.STATS.snapshot()["host_syncs"] == step + 1
    out, _ = r.reconstruct_device()
    assert isinstance(out, jax.Array)
    assert lb.STATS.snapshot()["host_syncs"] == 3  # reconstruct adds none


def test_cross_reader_batched_delta_decode():
    """Same-shaped staged groups of different readers decode through shared
    vmapped launches (the store's cross-session serving batch)."""
    from repro.store.service import reconstruct_many
    x = gaussian_field((30, 30), seed=9)
    ref = rf.refactor_array(x, "t", levels=2)
    readers = [rt.ProgressiveReader(ref) for _ in range(4)]
    for r in readers:
        r._fetch_to(r.plan(1e-4))
    staged = sum(len(r.engine._pending) for r in readers)
    before = rcn.STATS.snapshot()
    outs = reconstruct_many(readers)
    after = rcn.STATS.snapshot()
    assert staged > 0
    # 4 readers' identical group shapes collapse into per-shape buckets
    assert after["delta_decode_batches"] - before["delta_decode_batches"] \
        == staged // len(readers)
    ref_out = np.asarray(outs[0][0])
    for o, b in outs[1:]:
        assert np.array_equal(np.asarray(o), ref_out)
    assert np.abs(ref_out - x).max() <= outs[0][1]


# ------------------------------------------------------------- CP halving cap

def test_cp_halving_loop_bounded():
    """Satellite: the CP estimator's eps-halving loop is capped — a
    pathological (denormal) tau terminates instead of spinning through
    hundreds of subnormal halvings."""
    x = np.full((1,), 0.5, np.float32)
    r = rf.refactor_array(x, "s")
    res = qq.progressive_qoi_retrieve([rt.ProgressiveReader(r)],
                                      qq.QoI("sum_squares"), 5e-324,
                                      method="cp", max_iters=5)
    assert res.iterations <= 5  # terminated; cap kept each iteration finite


def test_qoi_bitrate_mixed_size_fleet():
    """Satellite: bitrate normalizes by the summed element counts of a
    mixed-size fleet (e.g. a field plus a broadcastable scalar parameter),
    not n_elements[0] * n_vars."""
    a = gaussian_field((4096,), seed=1)
    b = np.full((1,), 0.75, np.float32)  # broadcasts against the field
    readers = [rt.ProgressiveReader(rf.refactor_array(v, n))
               for v, n in [(a, "a"), (b, "b")]]
    res = qq.progressive_qoi_retrieve(readers, qq.V_TOTAL, 1e-1, method="mape")
    assert res.bytes_fetched > 0
    assert res.bitrate == pytest.approx(
        8.0 * res.bytes_fetched / (a.size + b.size))
