"""Unified tracing & metrics layer (repro.obs) + the CI regression gate.

Pins the observability contracts:
  * span/event mechanics: nesting, thread joining, disabled fast path;
  * sync-budget attribution: a traced >=4-chunk pipelined write produces a
    Chrome trace whose host_sync event count exactly matches the codec
    engine's counters (3 per chunk, labeled), and the read path adds
    1/chunk;
  * context-local stats (``lossless_batch.stats_scope``): concurrent scopes
    never cross-contaminate, worker threads join their caller's scope;
  * per-device Chrome-trace tracks for the sharded write path;
  * store metrics: compression accounting + expansion warning, backend
    cache hit/miss across cached re-reads and sessions,
    ``RetrievalService.stats()``;
  * ``benchmarks/check_regressions.py``: passes on an artifact that meets
    its budgets, fails non-zero on a doctored one (and on missing artifacts
    or unresolvable budget paths).
"""
import json
import logging
import threading

import numpy as np
import pytest

from repro import obs
from repro.core import lossless_batch as lb
from repro.core import pipeline as pl
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.data.fields import gaussian_field
from repro.store import (CachingBackend, DatasetStore, DatasetWriter,
                         InMemoryBackend, LocalFileBackend, RetrievalService)


# ------------------------------------------------------------ span mechanics

def test_span_disabled_is_shared_null():
    """Off the tracing path, span() must return the shared no-op manager
    (one ContextVar read, no allocation — the <2% overhead contract)."""
    assert obs_trace.current_tracer() is None
    s1 = obs_trace.span("write.copy_in", chunk=1)
    s2 = obs_trace.span("anything")
    assert s1 is obs_trace.NULL_SPAN and s2 is obs_trace.NULL_SPAN
    with s1:  # usable as a context manager
        obs_trace.event("host_sync", label="x")  # and events are no-ops


def test_nested_spans_events_and_attribution():
    with obs_trace.tracing() as tr:
        with obs_trace.span("outer", name="v"):
            with obs_trace.span("inner", chunk=3):
                obs_trace.event(obs_trace.EV_HOST_SYNC, label="codec.stats")
            obs_trace.event(obs_trace.EV_HOST_SYNC)  # unlabeled -> span name
        obs_trace.event(obs_trace.EV_DISPATCH)  # orphan
    spans = {s.name: s for s in tr.spans()}
    assert spans["inner"].parent_id == spans["outer"].span_id
    assert spans["outer"].parent_id is None
    assert spans["outer"].attrs == {"name": "v"}  # attr may be called "name"
    assert tr.event_counts() == {"host_sync": 2, "dispatch": 1}
    assert tr.attribute_events(obs_trace.EV_HOST_SYNC) == {
        "codec.stats": 1, "outer": 1}
    assert len(tr.orphan_events()) == 1
    assert tr.summary()["host_syncs_by_span"] == {"codec.stats": 1,
                                                  "outer": 1}


def test_wrap_for_thread_joins_callers_trace():
    with obs_trace.tracing() as tr:
        def work():
            with obs_trace.span("worker.span"):
                obs_trace.event(obs_trace.EV_SERIALIZE, bytes=10)
        t = threading.Thread(target=obs_trace.wrap_for_thread(work))
        t.start(); t.join()
        # a bare thread (no wrap) must NOT land in the trace
        t2 = threading.Thread(target=lambda: obs_trace.event("host_sync"))
        t2.start(); t2.join()
    names = [s.name for s in tr.spans()]
    assert names == ["worker.span"]
    assert tr.event_counts() == {"serialize": 1}


def test_no_tracing_scope_disables():
    with obs_trace.tracing() as tr:
        with obs_trace.no_tracing():
            assert obs_trace.span("x") is obs_trace.NULL_SPAN
            obs_trace.event("host_sync")
        with obs_trace.span("y"):
            pass
    assert [s.name for s in tr.spans()] == ["y"]
    assert tr.event_counts() == {}


# ------------------------------------------------------------------ metrics

def test_metrics_counters_gauges_histograms():
    # earlier suites encode for real and land series in the default
    # registry, so isolation is asserted as "unchanged", not "absent"
    default_before = obs_metrics.snapshot()["counters"].get(
        "codec.bytes_in{codec=huffman}")
    with obs_metrics.scope() as m:
        m.inc("codec.bytes_in", 100, codec="huffman")
        m.inc("codec.bytes_in", 50, codec="huffman")
        m.inc("codec.bytes_in", 7, codec="rle")
        m.gauge("store.compression_ratio", 1.5, var="v")
        for v in [1.0, 2.0, 3.0, 4.0]:
            m.observe("serve.retrieve_s", v)
        snap = m.snapshot()
    assert snap["counters"]["codec.bytes_in{codec=huffman}"] == 150
    assert snap["counters"]["codec.bytes_in{codec=rle}"] == 7
    assert snap["gauges"]["store.compression_ratio{var=v}"] == 1.5
    h = snap["histograms"]["serve.retrieve_s"]
    assert h["count"] == 4 and h["min"] == 1.0 and h["max"] == 4.0
    assert h["p50"] == 2.0 and h["p99"] == 4.0
    # scope() isolated the numbers from the default registry
    assert obs_metrics.snapshot()["counters"].get(
        "codec.bytes_in{codec=huffman}") == default_before


def test_metrics_scope_isolation_across_threads():
    """Two concurrent scopes in different threads never share series."""
    out = {}

    def worker(tag, n):
        with obs_metrics.scope() as m:
            for _ in range(n):
                m.inc("c")
            out[tag] = m.counter_value("c")

    ts = [threading.Thread(target=worker, args=("a", 100)),
          threading.Thread(target=worker, args=("b", 7))]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert out == {"a": 100, "b": 7}


# --------------------------------------------- context-local lossless stats

def test_stats_scope_concurrent_isolation():
    """Satellite regression: lossless_batch.STATS is context-local — two
    scopes mutating concurrently (as dispatch-ahead worker threads do) never
    cross-contaminate, and the module global keeps its .add/.snapshot API."""
    import jax.numpy as jnp
    barrier = threading.Barrier(2)
    results = {}

    def worker(tag, n):
        with lb.stats_scope() as st:
            barrier.wait()
            for _ in range(n):
                lb.host_sync(jnp.zeros(4), label=f"test.{tag}")
            results[tag] = st.host_syncs

    ts = [threading.Thread(target=worker, args=("a", 5)),
          threading.Thread(target=worker, args=("b", 2))]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert results == {"a": 5, "b": 2}


def test_stats_scope_worker_thread_joins_caller():
    """A wrap_for_thread worker lands its counters in the caller's scope
    (the pipeline's prefetch/serialize threads rely on this)."""
    import jax.numpy as jnp
    with lb.stats_scope() as st:
        def work():
            lb.host_sync(jnp.zeros(4))

        t = threading.Thread(target=obs_trace.wrap_for_thread(work))
        t.start(); t.join()
        assert st.host_syncs == 1


# --------------------------------------------------- traced write sync budget

def _traced_write(n_chunks=4, chunk=4096, mesh=None, pipelined=True,
                  dispatch_ahead=2):
    x = gaussian_field((n_chunks * chunk,), slope=-2.0, seed=5)
    with obs_metrics.scope() as m, obs_trace.tracing() as tr, \
            lb.stats_scope() as st:
        pipe = pl.ChunkedRefactorPipeline(chunk_elems=chunk, levels=2,
                                          pipelined=pipelined, mesh=mesh,
                                          dispatch_ahead=dispatch_ahead)
        blobs = pipe.refactor(x, name="v")
    return x, blobs, tr, st, m


def test_traced_write_host_sync_budget_matches_chrome_trace():
    """Acceptance: a traced 4-chunk pipelined write's Chrome trace contains
    EXACTLY the host_sync events the codec counters promise — 3 per DRAINED
    WINDOW of dispatch_ahead(=2) chunks (one encode.scalars gather + codec
    stats + codec payload), each attributed to its originating label; the
    amortized per-chunk budget is 1.5, half the old 3/chunk round budget."""
    n, drains = 4, 2  # 4 chunks drain in 2 full windows of dispatch_ahead=2
    _, blobs, tr, st, m = _traced_write(n_chunks=n)
    assert len(blobs) == n
    assert st.host_syncs == 3 * drains
    trace_json = obs.chrome_trace(tr)
    assert obs_export.event_count(trace_json, "host_sync") == st.host_syncs
    assert tr.attribute_events(obs_trace.EV_HOST_SYNC) == {
        "encode.scalars": drains, "codec.stats": drains,
        "codec.payload": drains}
    # every write stage span is present, once per chunk (batched finishes
    # show up as one sharded.finish_many span per drain)
    per = tr.summary()["spans"]
    for stage in ["write.copy_in", "write.dispatch", "write.serialize"]:
        assert per[stage]["count"] == n, stage
    assert per["write.refactor"]["count"] == 1
    assert per["sharded.finish_many"]["count"] == drains
    snap = m.snapshot()
    assert snap["gauges"]["write.syncs_per_chunk"] == 3 * drains / n
    assert snap["gauges"]["write.dispatches_per_chunk"] == 1.0
    # async-drain attribution gauges: mean in-flight depth per device at
    # drain time equals the full window; idle accounting is present
    assert snap["gauges"]["write.inflight_depth.d0"] == 2.0
    assert snap["gauges"]["write.idle_at_drain_s"] >= 0.0


def test_traced_read_adds_one_sync_per_chunk():
    """The read path's budget: at most 1 host sync per chunk (codec.decode)
    — the '28 syncs for 7 chunks' finding is 3/chunk write + 1/chunk read.
    The decode sync fires only when non-dc (huffman/rle) groups decode, so
    the chunks must be big enough (> HybridConfig.size_threshold bytes per
    plane group) for Algorithm-2 to pick huffman."""
    n, chunk = 4, 32768
    x, blobs, *_ = _traced_write(n_chunks=n, chunk=chunk)
    with obs_trace.tracing() as tr, lb.stats_scope() as st:
        y = pl.ChunkedReconstructPipeline().reconstruct(blobs, tol=1e-4)
    assert np.abs(y - x.reshape(-1)).max() <= 1e-4
    assert st.host_syncs == n
    assert tr.attribute_events(obs_trace.EV_HOST_SYNC) == {"codec.decode": n}
    per = tr.summary()["spans"]
    assert per["read.decompress"]["count"] == n
    assert per["read.recompose"]["count"] == n


def test_serial_mode_budget_unchanged():
    n = 3
    _, blobs, tr, st, _ = _traced_write(n_chunks=n, pipelined=False)
    assert len(blobs) == n and st.host_syncs == 3 * n
    assert sum(tr.attribute_events(obs_trace.EV_HOST_SYNC).values()) == 3 * n


# ------------------------------------------------------ per-device tracks

def test_mesh_of_one_has_single_device_track():
    from repro.core import sharded as shd
    _, _, tr, _, _ = _traced_write(n_chunks=4, mesh=shd.make_chunk_mesh(1))
    trace_json = obs.chrome_trace(tr)
    assert obs_export.device_tracks(trace_json) == ["device:0"]


def test_two_device_sharded_write_two_device_tracks(subproc):
    """Acceptance: a traced 2-device sharded write exports a Chrome trace
    with two distinct device tracks carrying that device's chunk spans."""
    out = subproc("""
        import json
        import numpy as np, jax
        assert len(jax.devices()) >= 2
        from repro import obs
        from repro.core import pipeline as pl, sharded as shd
        from repro.obs import export as ex
        from repro.obs import trace as obs_trace
        x = np.random.default_rng(3).standard_normal(4 * 4096).astype(np.float32)
        with obs_trace.tracing() as tr:
            pl.ChunkedRefactorPipeline(chunk_elems=4096, levels=2,
                                       mesh=shd.make_chunk_mesh(2)
                                       ).refactor(x, "v")
        tj = obs.chrome_trace(tr)
        tracks = ex.device_tracks(tj)
        assert tracks == ["device:0", "device:1"], tracks
        # round-robin: chunks 0,2 on device 0; 1,3 on device 1
        by_dev = {}
        for s in tr.spans():
            if s.name == "sharded.dispatch":
                by_dev.setdefault(s.attrs["device"], []).append(s.attrs["chunk"])
        assert {d: sorted(cs) for d, cs in by_dev.items()} == \
            {0: [0, 2], 1: [1, 3]}
        print("TRACKS " + json.dumps(tracks))
    """, n_devices=2)
    assert "TRACKS" in out


# -------------------------------------------------------- store accounting

def _write_store(tmp_path, x, name="v", chunk_elems=4096):
    root = str(tmp_path / "store")
    with DatasetWriter(root, chunk_elems=chunk_elems) as w:
        entry = w.write(name, x)
    return root, entry


def test_writer_compression_metrics_and_expansion_warning(tmp_path, caplog):
    """Satellite: the writer records raw/stored bytes + ratio per variable
    and warns loudly when a write EXPANDS the data (stored > raw)."""
    # white noise with per-element random exponents defeats the lossless
    # stage -> guaranteed expansion (bitplane + group framing overhead)
    rng = np.random.default_rng(0)
    x = (rng.standard_normal(8192)
         * np.exp(rng.uniform(-30, 30, 8192))).astype(np.float32)
    with obs_metrics.scope() as m:
        with caplog.at_level(logging.WARNING, logger="repro.store"):
            root, entry = _write_store(tmp_path, x)
    raw, stored = x.nbytes, entry.stored_bytes
    assert stored > raw  # the premise of the warning
    assert any("EXPANDED" in r.message for r in caplog.records)
    snap = m.snapshot()
    assert snap["counters"]["store.bytes_raw{var=v}"] == raw
    assert snap["counters"]["store.bytes_stored{var=v}"] == stored
    assert snap["gauges"]["store.compression_ratio{var=v}"] == \
        pytest.approx(raw / stored)


def test_writer_no_warning_on_compressible_data(tmp_path, caplog):
    # one large chunk of a smooth field compresses (ratio > 1): per-chunk
    # framing overhead is what drives small-chunk expansion (ROADMAP item)
    x = gaussian_field((32768,), slope=-3.0, seed=11)
    with caplog.at_level(logging.WARNING, logger="repro.store"):
        _write_store(tmp_path, x, chunk_elems=32768)
    assert not [r for r in caplog.records if "EXPANDED" in r.message]


def test_backend_stats_cached_reread_and_service_stats(tmp_path):
    """Satellite: BackendStats across a cached re-read + multi-session
    serving, surfaced through layout.stats() and RetrievalService.stats()."""
    x = gaussian_field((3 * 4096,), slope=-2.0, seed=9)
    root, entry = _write_store(tmp_path, x)
    store = DatasetStore.open(
        root, backend=CachingBackend(LocalFileBackend(root)))
    # serving=False: the subject is the BYTE cache's hit accounting; the
    # serving tier's plane cache would serve session 2 without any backend
    # reads at all (tests/test_serving.py covers that layer)
    svc = RetrievalService(store, serving=False)
    tol = 1e-3 * float(x.max() - x.min())

    s1 = svc.open_session()
    xh, _, fetched1 = s1.retrieve("v", tol)
    assert fetched1 > 0 and np.abs(xh - x.reshape(-1)).max() <= tol
    st1 = store.stats().snapshot()
    assert st1["cache_misses"] > 0 and st1["bytes_fetched"] > 0
    misses_after_first = st1["cache_misses"]

    # a second session re-reads the same ranges: all hits, no new fetches
    s2 = svc.open_session()
    _, _, fetched2 = s2.retrieve("v", tol)
    st2 = store.stats().snapshot()
    assert st2["cache_misses"] == misses_after_first
    assert st2["cache_hits"] > st1["cache_hits"]
    assert st2["bytes_fetched"] == st1["bytes_fetched"]
    assert 0 < st2["hit_rate"] < 1

    # service-level stats: per-session accounting + backend snapshot
    stats = svc.stats()
    assert stats["store_bytes"] == entry.stored_bytes
    assert stats["backend"] == st2
    assert stats["sessions"][s1.sid]["requests"] == 1
    assert stats["sessions"][s1.sid]["bytes_fetched"] == fetched1
    assert stats["sessions"][s2.sid]["bytes_fetched"] == fetched2
    # a tighter request on session 1 is incremental: only delta bytes
    _, _, fetched3 = s1.retrieve("v", tol / 10)
    assert svc.stats()["sessions"][s1.sid]["requests"] == 2
    assert svc.stats()["sessions"][s1.sid]["bytes_fetched"] == \
        fetched1 + fetched3
    svc.close_session(s2)
    assert s2.sid not in svc.stats()["sessions"]
    store.close()


def test_backend_read_events_and_metrics(tmp_path):
    x = gaussian_field((2 * 4096,), slope=-2.0, seed=4)
    root, _ = _write_store(tmp_path, x)
    store = DatasetStore.open(
        root, backend=CachingBackend(LocalFileBackend(root)))
    svc = RetrievalService(store)
    tol = 1e-2 * float(x.max() - x.min())
    with obs_metrics.scope() as m, obs_trace.tracing() as tr:
        svc.open_session().retrieve("v", tol)
    snap = m.snapshot()
    reads = tr.events(obs_trace.EV_BACKEND_READ)
    assert reads, "cache-backed retrieval must emit backend_read events"
    assert snap["counters"]["backend.bytes_served"] == \
        sum(ev.attrs["bytes"] for _, ev in reads)
    assert snap["counters"]["serve.requests"] == 1
    assert snap["counters"]["serve.bytes_fetched"] > 0
    assert snap["histograms"]["serve.retrieve_s"]["count"] == 1
    per = tr.summary()["spans"]
    assert per["serve.retrieve"]["count"] == 1
    assert "serve.fetch" in per
    store.close()


# ------------------------------------------------------- regression gate

def _gate(tmp_path, artifact: dict, budgets: list) -> int:
    from benchmarks import check_regressions as cr
    art_dir = tmp_path / "artifacts"
    base_dir = tmp_path / "baselines"
    art_dir.mkdir(exist_ok=True)
    base_dir.mkdir(exist_ok=True)
    (art_dir / "bench.json").write_text(json.dumps(artifact))
    (base_dir / "bench.json").write_text(json.dumps(
        {"artifact": "bench.json", "budgets": budgets}))
    return cr.main(["--baselines", str(base_dir), "--artifacts", str(art_dir)])


def test_check_regressions_passes_within_budget(tmp_path):
    art = {"syncs_per_chunk": 4.0, "pipelined": {"codec": {"host_syncs": 21}},
           "compression_ratio": 1.8}
    assert _gate(tmp_path, art, [
        {"path": "syncs_per_chunk", "op": "<=", "value": 4.0},
        {"path": "pipelined.codec.host_syncs", "op": "<=", "value": 25},
        {"path": "compression_ratio", "op": ">=", "value": 1.5},
    ]) == 0


def test_check_regressions_fails_on_doctored_snapshot(tmp_path):
    """Acceptance: doctor the artifact past any single budget -> exit 1."""
    art = {"syncs_per_chunk": 6.0,  # doctored: budget is 4
           "pipelined": {"codec": {"host_syncs": 21}}}
    assert _gate(tmp_path, art, [
        {"path": "syncs_per_chunk", "op": "<=", "value": 4.0,
         "note": "3/chunk write + 1/chunk read"},
        {"path": "pipelined.codec.host_syncs", "op": "<=", "value": 25},
    ]) == 1


def test_check_regressions_fails_on_missing_artifact_or_path(tmp_path):
    from benchmarks import check_regressions as cr
    base_dir = tmp_path / "baselines"
    base_dir.mkdir()
    (base_dir / "b.json").write_text(json.dumps(
        {"artifact": "nope.json",
         "budgets": [{"path": "x", "op": "<=", "value": 1}]}))
    empty_art = tmp_path / "artifacts"
    empty_art.mkdir()
    assert cr.main(["--baselines", str(base_dir),
                    "--artifacts", str(empty_art)]) == 1
    # artifact present but budget path unresolvable -> still a failure
    assert _gate(tmp_path, {"present": 1},
                 [{"path": "absent.leaf", "op": "<=", "value": 1}]) == 1


def test_check_regressions_real_baselines_are_wellformed():
    """Every committed baseline parses, names a real benchmark artifact
    name, and uses known ops (the gate itself runs in the CI bench job)."""
    from benchmarks import check_regressions as cr
    specs = sorted(cr.BASELINES.glob("*.json"))
    assert specs, "no committed baselines under benchmarks/baselines/"
    for p in specs:
        spec = json.loads(p.read_text())
        assert spec["artifact"].endswith(".json")
        assert spec["budgets"], p.name
        for b in spec["budgets"]:
            assert b["op"] in cr.OPS, (p.name, b)
            assert isinstance(b["value"], (int, float)), (p.name, b)


# ------------------------------------------------------- benchmark artifact

def test_write_json_attaches_obs_section(tmp_path, monkeypatch):
    from benchmarks import common
    monkeypatch.setattr(common, "REPO", tmp_path)
    with obs_metrics.scope() as m, obs_trace.tracing():
        with obs_trace.span("bench.stage", chunk=0):
            obs_trace.event(obs_trace.EV_HOST_SYNC, label="codec.stats")
        m.inc("store.bytes_raw", 100)
        path = common.write_json("t", {"x": 1})
    data = json.loads(path.read_text())
    assert data["x"] == 1
    assert data["obs"]["metrics"]["counters"]["store.bytes_raw"] == 100
    assert data["obs"]["trace_summary"]["host_syncs_by_span"] == {
        "codec.stats": 1}
    trace_file = path.parent / data["obs"]["trace_file"]
    tj = json.loads(trace_file.read_text())
    assert obs_export.event_count(tj, "host_sync") == 1


def test_write_json_plain_without_obs(tmp_path, monkeypatch):
    from benchmarks import common
    monkeypatch.setattr(common, "REPO", tmp_path)
    # fresh empty metrics scope + no tracer: the artifact stays plain
    with obs_metrics.scope():
        path = common.write_json("t2", {"x": 2})
    assert "obs" not in json.loads(path.read_text())
