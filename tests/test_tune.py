"""Tests for the ``repro.tune`` autotune subsystem (PR 7).

Covers the contracts the rest of the stack leans on:

  * ``RefactorConfig`` JSON round-trip, unknown-key tolerance (manifest
    forward-compat), and ``as_config`` precedence (explicit legacy kwargs >
    ``config=`` > defaults);
  * ``lossless.exact_stored_bytes`` matches REAL ``Segment.to_bytes()``
    serializations for every codec (the property the Algorithm-2 store-raw
    fallback depends on), and the fallback never lets a chosen codec expand
    past storing the group raw;
  * the batched engine's ``_select`` mirrors ``compress_group``
    decision-for-decision, fallback included;
  * the ``config=`` spelling is byte-identical to the legacy loose kwargs
    through ``refactor_array`` (fused and per-piece paths);
  * the on-disk cache: store/load, hit/miss counters, corrupt-entry
    tolerance, ``REPRO_TUNE_CACHE`` override, and ``cached_config``;
  * ``tune()`` search logic with the cost model and probe runner stubbed
    out (fast): measured-best-wins, default-always-probed (winner can only
    tie or beat it), cache hit on the second call with NO re-search;
  * one real ``CostModel`` lowering on a small shape (HBM bytes > 0,
    probe calibration moves the scale).
"""
import json

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import tune as tn
from repro.core import lossless as ll
from repro.tune import cache as tcache
from repro.tune import search as tsearch
from repro.tune.config import DEFAULT_CONFIG, RefactorConfig, as_config


# ------------------------------------------------------------------ config --

@settings(max_examples=20, deadline=None)
@given(st.sampled_from(["register_block", "locality", "shuffle"]),
       st.sampled_from([4, 8, 16]),
       st.sampled_from(["naive", "butterfly"]),
       st.integers(1, 16), st.integers(1, 8), st.integers(1, 4))
def test_config_json_roundtrip(design, tiles, unroll, group_size,
                               dispatch_ahead, depth):
    cfg = RefactorConfig(design=design, tiles_per_block=tiles, unroll=unroll,
                         group_size=group_size, dispatch_ahead=dispatch_ahead,
                         depth=depth)
    j = cfg.to_json()
    assert RefactorConfig.from_json(j) == cfg
    # JSON-serializable end to end (what the manifest / cache files store)
    assert RefactorConfig.from_json(json.loads(json.dumps(j))) == cfg


def test_config_from_json_ignores_unknown_keys():
    j = DEFAULT_CONFIG.to_json()
    j["from_the_future"] = {"nested": True}
    j["another"] = 7
    assert RefactorConfig.from_json(j) == DEFAULT_CONFIG


def test_as_config_precedence():
    base = RefactorConfig(design="locality", group_size=8, depth=3)
    # no explicit kwargs: the config passes through untouched (same object)
    assert as_config(base) is base
    assert as_config(None) is DEFAULT_CONFIG
    # explicit legacy kwargs override the base config's fields
    out = as_config(base, design="shuffle", depth=1)
    assert out.design == "shuffle" and out.depth == 1
    assert out.group_size == 8          # untouched fields come from base
    # a hybrid kwarg maps onto the three lossless-policy fields
    hyb = ll.HybridConfig(group_size=2, size_threshold=123, cr_threshold=1.5)
    out = as_config(base, hybrid=hyb)
    assert (out.group_size, out.size_threshold, out.cr_threshold) \
        == (2, 123, 1.5)


def test_program_key_ignores_pipeline_knobs():
    a = DEFAULT_CONFIG
    b = a.replace(dispatch_ahead=4, depth=3, chunk_elems=1 << 12,
                  size_threshold=1, cr_threshold=2.0)
    assert a.program_key() == b.program_key()   # one lowering, shared
    assert a.replace(design="locality").program_key() != a.program_key()


# ------------------------------------------- exact sizes + store-raw fallback

def _profile(kind: str, n: int, rng: np.random.Generator) -> np.ndarray:
    if kind == "const":
        return np.zeros(n, np.uint8)
    if kind == "runs":
        return np.repeat(rng.integers(0, 4, n // 64 + 1).astype(np.uint8),
                         64)[:n]
    if kind == "skew":
        p = np.r_[0.95, np.full(255, 0.05 / 255)]
        return rng.choice(np.arange(256, dtype=np.uint8), n, p=p)
    return rng.integers(0, 256, n).astype(np.uint8)    # incompressible


@settings(max_examples=12, deadline=None)
@given(st.sampled_from([4097, 5000, 8191, 8192, 12288]),
       st.sampled_from(["const", "runs", "skew", "random"]))
def test_exact_stored_bytes_matches_real_serialization(n, kind):
    """The fallback's size oracle is EXACT: ``exact_stored_bytes`` computed
    from selection-time stats equals ``len(Segment.to_bytes())`` of the real
    encoder output, for every codec."""
    import jax.numpy as jnp

    d = _profile(kind, n, np.random.default_rng(n * 31 + len(kind)))
    hist = np.bincount(d, minlength=256)
    bits = int(np.sum(hist * ll.build_codebook(hist)[0].astype(np.int64)))
    _, _, nruns = ll._rle_scan(jnp.asarray(d))
    assert len(ll.dc_encode(d).to_bytes()) == ll.exact_stored_bytes("dc", n)
    assert len(ll.huffman_encode(d).to_bytes()) \
        == ll.exact_stored_bytes("huffman", n, total_bits=bits)
    assert len(ll.rle_encode(d).to_bytes()) \
        == ll.exact_stored_bytes("rle", n, n_runs=int(nruns))


def test_exact_stored_bytes_unknown_method():
    with pytest.raises(ValueError):
        ll.exact_stored_bytes("zstd", 10)


@settings(max_examples=12, deadline=None)
@given(st.sampled_from([4097, 5000, 8192, 12288]),
       st.sampled_from(["const", "runs", "skew", "random"]))
def test_store_raw_fallback_never_expands(n, kind):
    """Algorithm-2 with the fallback: whatever codec wins, the serialized
    group is never larger than storing it raw — and still round-trips."""
    d = _profile(kind, n, np.random.default_rng(n * 17 + len(kind)))
    cfg = ll.HybridConfig(size_threshold=4096)
    seg = ll.compress_group(d, cfg)
    assert len(seg.to_bytes()) <= ll.exact_stored_bytes("dc", n)
    np.testing.assert_array_equal(ll.decompress_group(seg), d)


def test_fallback_picks_dc_near_break_even():
    """Incompressible bytes: the huffman CR estimator can sit just above the
    threshold while the exact serialization expands — the fallback must
    store raw.  (Random uint8 huffman-codes to ~8 bits/sym + codebook, so
    the exact size always exceeds dc's n + 50.)"""
    d = np.random.default_rng(3).integers(0, 256, 8192).astype(np.uint8)
    seg = ll.compress_group(d, ll.HybridConfig(cr_threshold=0.5))
    assert seg.method == "dc"
    # force modes skip the fallback: benchmarks measure the codec asked for
    forced = ll.compress_group(d, ll.HybridConfig(force="huffman"))
    assert forced.method == "huffman"
    assert len(forced.to_bytes()) > ll.exact_stored_bytes("dc", d.size)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([4097, 5000, 8192]),
       st.sampled_from(["const", "runs", "skew", "random"]))
def test_batched_select_mirrors_compress_group(n, kind):
    """The batched engine's host-side ``_select`` makes the same call as
    ``compress_group`` — fallback included — and the full batched encode is
    byte-identical to the per-group reference."""
    import jax.numpy as jnp

    from repro.core import lossless_batch as lb

    d = _profile(kind, n, np.random.default_rng(n * 7 + len(kind)))
    cfg = ll.HybridConfig(size_threshold=4096)
    ref = ll.compress_group(d, cfg)
    hist = np.bincount(d, minlength=256)
    _, _, nruns = ll._rle_scan(jnp.asarray(d))
    method, _ = lb._select(n, hist, int(nruns), cfg)
    assert method == ref.method
    (seg,) = lb.encode_groups([jnp.asarray(d)], cfg)
    assert seg.to_bytes() == ref.to_bytes()


# ------------------------------------------------- config path byte-identity

@pytest.mark.parametrize("fused", [True, False])
def test_config_path_matches_legacy_kwargs(fused):
    """``config=`` and the legacy loose kwargs are the same write: every
    serialized segment byte-identical (the tuned path can never change the
    bytes a given effective config produces)."""
    from repro.core import refactor as rf

    rng = np.random.default_rng(5)
    t = np.linspace(0.0, 4.0, 4096, dtype=np.float64)
    x = (np.sin(t) + 0.02 * rng.standard_normal(4096)).astype(np.float32)

    legacy = rf.refactor_array(
        x, levels=2, design="locality",
        hybrid=ll.HybridConfig(group_size=8), fused=fused)
    cfg = RefactorConfig(design="locality", group_size=8)
    viacfg = rf.refactor_array(x, levels=2, config=cfg, fused=fused)

    a = [(pi, k, gi, s.to_bytes()) for pi, k, gi, s in rf.iter_segments(legacy)]
    b = [(pi, k, gi, s.to_bytes()) for pi, k, gi, s in rf.iter_segments(viacfg)]
    assert a == b


# ------------------------------------------------------------------- cache --

def _isolate(tmp_path, monkeypatch):
    monkeypatch.setenv(tcache._ENV, str(tmp_path))
    tcache.invalidate_memo()
    tcache.STATS.reset()
    tsearch.STATS.reset()


def test_cache_store_load_and_stats(tmp_path, monkeypatch):
    _isolate(tmp_path, monkeypatch)
    cfg = RefactorConfig(design="shuffle", group_size=2)
    assert tcache.load("fp", "prob") is None            # cold: miss
    assert tcache.STATS.snapshot()["misses"] == 1
    p = tcache.store("fp", "prob", cfg, meta={"probe_s": 0.5})
    assert p.is_file() and str(p).startswith(str(tmp_path))
    assert tcache.load("fp", "prob") == cfg             # memo hit
    tcache.invalidate_memo()
    assert tcache.load("fp", "prob") == cfg             # disk hit
    snap = tcache.STATS.snapshot()
    assert snap["hits"] == 2 and snap["stores"] == 1
    # the stored file carries the meta + identifying keys
    j = json.loads(p.read_text())
    assert j["meta"]["fingerprint"] == "fp" and j["meta"]["probe_s"] == 0.5


def test_cache_corrupt_entry_is_miss(tmp_path, monkeypatch):
    _isolate(tmp_path, monkeypatch)
    tcache.store("fp", "prob", DEFAULT_CONFIG)
    path = tcache._path(tcache.cache_root(), "fp", "prob")
    path.write_text("{not json")
    tcache.invalidate_memo()
    assert tcache.load("fp", "prob") is None            # never raises
    path.write_text(json.dumps({"wrong": "shape"}))
    tcache.invalidate_memo()
    assert tcache.load("fp", "prob") is None


def test_cached_config_consults_env_root(tmp_path, monkeypatch):
    """``cached_config`` (the writer/pipeline lookup) resolves the same
    fingerprint+problem keying as ``tune`` and honors REPRO_TUNE_CACHE."""
    _isolate(tmp_path, monkeypatch)
    shape, levels = (2048,), 2
    assert tn.cached_config(shape, levels=levels) is None
    fp = tcache.backend_fingerprint("auto", 1)
    prob = tcache.problem_key(shape, "float32", levels)
    cfg = RefactorConfig(design="locality")
    tcache.store(fp, prob, cfg)
    assert tn.cached_config(shape, levels=levels) == cfg
    # different problem key: still a miss
    assert tn.cached_config((4096,), levels=levels) is None


# ---------------------------------------------------------------- tune() ----

class _FakeModel:
    """Stands in for ``CostModel``: deterministic scores, no lowering."""

    def __init__(self, shape, levels=None, dtype="float32", peaks=None):
        self.scale = 1.0

    def score(self, cfg):
        # prefer shuffle/group-8 so the probe set reliably contains it
        return 0.1 if (cfg.design == "shuffle" and cfg.group_size == 8) \
            else 1.0

    def calibrate(self, cfg, measured_s):
        self.scale = measured_s
        return self.scale


def _patch_tuner(monkeypatch, measure, pipeline_measure=None,
                 read_measure=None):
    monkeypatch.setattr(tsearch, "CostModel", _FakeModel)
    monkeypatch.setattr(tsearch, "_measure_write", measure)
    # the dispatch_ahead probe runs real multi-chunk pipelined writes;
    # fake it too (deterministic: the default depth measures fastest) so
    # tune() tests stay compile-free
    monkeypatch.setattr(
        tsearch, "_measure_pipeline_write",
        pipeline_measure if pipeline_measure is not None
        else (lambda x, cfg, levels, repeats=2:
              0.5 if cfg.dispatch_ahead == DEFAULT_CONFIG.dispatch_ahead
              else 1.0))
    # likewise the read-depth probe (real pipelined write + reads): stub
    # both the blob production and the measured reconstruct
    monkeypatch.setattr(
        tsearch, "_probe_blobs",
        lambda best, n, levels, dtype, n_chunks:
        (np.linspace(0.0, 1.0, n_chunks * n, dtype=np.float32),
         [b"blob"] * n_chunks))
    monkeypatch.setattr(
        tsearch, "_measure_pipeline_read",
        read_measure if read_measure is not None
        else (lambda blobs, cfg, tol, repeats=2:
              0.5 if cfg.depth == DEFAULT_CONFIG.depth else 1.0))


def test_tune_measured_best_wins_then_cache_hit(tmp_path, monkeypatch):
    _isolate(tmp_path, monkeypatch)

    def measure(x, cfg, levels, repeats=2):
        return 0.25 if (cfg.design == "shuffle" and cfg.group_size == 8) \
            else 1.0

    _patch_tuner(monkeypatch, measure)
    r1 = tn.tune((1024,), levels=2, probes=2)
    assert not r1.cache_hit
    assert r1.config.design == "shuffle" and r1.config.group_size == 8
    assert r1.config.dispatch_ahead in tsearch.DISPATCH_AHEAD
    assert r1.probes and min(s for _, s in r1.probes) == 0.25
    s1 = tsearch.STATS.snapshot()
    assert s1["searches"] == 1 and s1["candidates_scored"] > 0

    # second call: cached winner replayed, NO search, NO scoring
    r2 = tn.tune((1024,), levels=2, probes=2)
    assert r2.cache_hit and r2.config == r1.config
    assert r2.scores == () and r2.probes == ()
    assert tsearch.STATS.snapshot() == s1
    # force=True ignores the hit but refreshes the cache
    r3 = tn.tune((1024,), levels=2, probes=2, force=True)
    assert not r3.cache_hit and r3.config == r1.config
    assert tsearch.STATS.snapshot()["searches"] == 2


def test_tune_winner_never_loses_to_default(tmp_path, monkeypatch):
    """The default config is ALWAYS probed; when nothing measures faster,
    the tuner returns it unchanged (tuning can't regress the default)."""
    _isolate(tmp_path, monkeypatch)
    probed = []

    def measure(x, cfg, levels, repeats=2):
        probed.append(cfg)
        return 1.0                       # everything ties: first probe wins

    _patch_tuner(monkeypatch, measure)
    r = tn.tune((512,), levels=1, probes=3)
    assert probed[0] == DEFAULT_CONFIG   # default heads the probe set
    assert r.config == DEFAULT_CONFIG


def test_tune_survives_probe_failures(tmp_path, monkeypatch):
    _isolate(tmp_path, monkeypatch)

    def measure(x, cfg, levels, repeats=2):
        raise RuntimeError("probe exploded")

    _patch_tuner(monkeypatch, measure)
    r = tn.tune((512,), levels=1, probes=2)
    assert r.config == DEFAULT_CONFIG    # pathological: default, cached
    assert tn.tune((512,), levels=1).cache_hit


# -------------------------------------------------------------- cost model --

def test_cost_model_real_program():
    """One real lowering: the fused program's HLO yields a nonzero memory
    term (FLOPs may legitimately be 0 — the encode chain is bitwise), and a
    probe calibration rescales predictions to measured units."""
    from repro.tune.cost import CostModel

    m = CostModel((256,), levels=1)
    cost = m.cost(DEFAULT_CONFIG)
    assert cost.hbm_bytes > 0
    assert m.score(DEFAULT_CONFIG) > 0
    before = m.score(DEFAULT_CONFIG)
    m.calibrate(DEFAULT_CONFIG, measured_s=before * 10)
    assert m.score(DEFAULT_CONFIG) == pytest.approx(before * 10)
    # pipeline-knob-only variants share the lowering cache
    assert m.cost(DEFAULT_CONFIG.replace(dispatch_ahead=4)) is cost


def test_tune_probes_dispatch_ahead_through_pipeline(tmp_path, monkeypatch):
    """The window-depth knob is picked by MEASURED multi-chunk pipelined
    probes (one per candidate depth, on probe-shape chunks), and the probe
    chunking never leaks into the cached winner."""
    _isolate(tmp_path, monkeypatch)
    seen = []

    def pmeasure(x, cfg, levels, repeats=2):
        seen.append((cfg.dispatch_ahead, cfg.chunk_elems, x.size))
        return {1: 0.9, 2: 0.2, 4: 0.8}[cfg.dispatch_ahead]

    _patch_tuner(monkeypatch, lambda x, cfg, levels, repeats=2: 1.0,
                 pipeline_measure=pmeasure)
    r = tn.tune((1024,), levels=2, probes=1)
    assert r.config.dispatch_ahead == 2  # fastest measured depth wins
    assert [d for d, _, _ in seen] == list(tsearch.DISPATCH_AHEAD)
    assert all(ce == 1024 and nx == 6 * 1024 for _, ce, nx in seen)
    assert r.config.chunk_elems == DEFAULT_CONFIG.chunk_elems
    # the depth survives the cache round-trip
    assert tn.tune((1024,), levels=2).config.dispatch_ahead == 2


def test_tune_probes_read_depth_through_pipeline(tmp_path, monkeypatch):
    """The read-side ``depth`` knob (ROADMAP gap from PR 8) is picked by
    MEASURED pipelined reconstructs of the winner's own probe blobs — one
    per candidate depth — recorded in the winner (and thus the manifest
    plan), with the probe chunking never leaking into the cached config."""
    _isolate(tmp_path, monkeypatch)
    seen = []

    def rmeasure(blobs, cfg, tol, repeats=2):
        seen.append((cfg.depth, cfg.chunk_elems, len(blobs)))
        return {1: 0.8, 2: 0.9, 4: 0.1}[cfg.depth]

    _patch_tuner(monkeypatch, lambda x, cfg, levels, repeats=2: 1.0,
                 read_measure=rmeasure)
    r = tn.tune((1024,), levels=2, probes=1)
    assert r.config.depth == 4           # fastest measured depth wins
    assert [d for d, _, _ in seen] == list(tsearch.DEPTHS)
    assert all(ce == 1024 and nb == 6 for _, ce, nb in seen)
    assert r.config.chunk_elems == DEFAULT_CONFIG.chunk_elems
    # the depth survives the cache round-trip (what store readers replay
    # from the manifest plan)
    assert tn.tune((1024,), levels=2).config.depth == 4
    # and the cache file records the per-depth probe curve
    p = tcache._path(tcache.cache_root(),
                     tcache.backend_fingerprint("auto", 1),
                     tcache.problem_key((1024,), "float32", 2))
    meta = json.loads(p.read_text())["meta"]
    assert [d for d, _ in meta["depth_probes"]] == list(tsearch.DEPTHS)


def test_platform_peaks_calibrated_from_roofline_artifact(tmp_path,
                                                          monkeypatch):
    """tune.cost reads the machine's roofline.json 'calibrated' section when
    present (env-pointed artifact), and falls back to NOMINAL_PEAKS on any
    platform mismatch, corruption, or unusable rates."""
    from repro.tune import cost as tc

    art = tmp_path / "roofline.json"
    art.write_text(json.dumps({"calibrated": {
        "platform": "cpu", "scale": 2.0,
        "flops": 5e10, "hbm_bw": 1.5e10, "link_bw": 5e9}}))
    monkeypatch.setenv(tc.ROOFLINE_ARTIFACT_ENV, str(art))
    p = tc.platform_peaks("cpu")
    assert (p.flops, p.hbm_bw, p.link_bw) == (5e10, 1.5e10, 5e9)
    # another platform's artifact must not apply
    assert tc.platform_peaks("gpu") == tc.NOMINAL_PEAKS["gpu"]
    # corrupt artifact -> nominal, never an exception
    art.write_text("{not json")
    assert tc.platform_peaks("cpu") == tc.NOMINAL_PEAKS["cpu"]
    # zero/non-finite rates are unusable -> nominal
    art.write_text(json.dumps({"calibrated": {
        "platform": "cpu", "flops": 0.0, "hbm_bw": 1e9, "link_bw": 1e9}}))
    assert tc.platform_peaks("cpu") == tc.NOMINAL_PEAKS["cpu"]
    # absent artifact -> nominal
    monkeypatch.delenv(tc.ROOFLINE_ARTIFACT_ENV)
    monkeypatch.chdir(tmp_path)
    assert tc.platform_peaks("cpu") == tc.NOMINAL_PEAKS["cpu"]
