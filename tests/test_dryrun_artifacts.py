"""Validate the committed multi-pod dry-run artifacts: full cell coverage on
both production meshes, zero failures, and roofline-input invariants.
(The artifacts are produced by `python -m repro.launch.dryrun`; this test
guards against regressions in the recorded evidence.)"""
import itertools
import json
from pathlib import Path

import pytest

OUT = Path(__file__).resolve().parents[1] / "out" / "dryrun"

ARCHS = ["rwkv6-3b", "deepseek-67b", "h2o-danube-3-4b", "command-r-plus-104b",
         "qwen2-7b", "hubert-xlarge", "jamba-v0.1-52b", "deepseek-v2-236b",
         "deepseek-v3-671b", "llama-3.2-vision-90b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

pytestmark = pytest.mark.skipif(not OUT.exists(),
                                reason="dry-run artifacts not generated")


def _load(a, s, m):
    p = OUT / f"{a}__{s}__{m}.json"
    assert p.exists(), f"missing dry-run cell {p.name}"
    return json.loads(p.read_text())


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_all_cells_present_and_green(mesh):
    ok = skip = 0
    for a, s in itertools.product(ARCHS, SHAPES):
        r = _load(a, s, mesh)
        assert r["status"] in ("ok", "skip"), (a, s, mesh, r.get("error"))
        ok += r["status"] == "ok"
        skip += r["status"] == "skip"
    assert ok == 32 and skip == 8  # DESIGN.md §7


def test_roofline_inputs_sane():
    for a, s in itertools.product(ARCHS, SHAPES):
        r = _load(a, s, "single")
        if r["status"] != "ok":
            continue
        assert r["flops_per_device"] > 0, (a, s)
        assert r["hbm_bytes_per_device"] > 0, (a, s)
        assert r["memory"]["argument_bytes"] > 0, (a, s)
        # sharded training states: arguments must fit far under one host
        assert r["memory"]["argument_bytes"] < 64e9, (a, s)


def test_multi_pod_extends_data_parallelism():
    """The pod axis must change the collective schedule (pod-crossing sync)."""
    for a in ["deepseek-67b", "command-r-plus-104b"]:
        single = _load(a, "train_4k", "single")
        multi = _load(a, "train_4k", "multi")
        ks = single["collectives"]["by_kind"]
        km = multi["collectives"]["by_kind"]
        assert set(km), (a, "multi-pod cell has no collectives?")
        # per-device batch halves -> compute per device drops
        assert multi["flops_per_device"] < single["flops_per_device"]


def test_perf_cells_improved():
    """§Perf: optimized variants beat the recorded baselines."""
    base_dir = OUT.parent / "dryrun_baseline"
    if not base_dir.exists():
        pytest.skip("baseline snapshot absent")

    def term(d, name):
        r = json.loads((d).read_text())
        return {"c": r["flops_per_device"],
                "x": r["collectives"]["wire_bytes_per_device"]}[name]

    b = term(base_dir / "deepseek-v3-671b__train_4k__single.json", "x")
    o = term(OUT / "deepseek-v3-671b__train_4k__single__moe_shard_map.json", "x")
    assert o < 0.2 * b  # >=5x on the collective term
    bc_ = term(base_dir / "deepseek-v3-671b__train_4k__single.json", "c")
    oc = term(OUT / "deepseek-v3-671b__train_4k__single__moe_shard_map.json", "c")
    assert oc < 0.2 * bc_
