"""Async per-device execution of the sharded write/read stack.

The pipelined write keeps ``dispatch_ahead`` fused encodes in flight per
device and drains them in full-window batches (one scalar gather + one
stacked codec pass per drain), so the amortized scalar-gather count per
chunk is ``1 / (dispatch_ahead * n_shards)`` — counter-tested here, along
with byte identity at every window depth, exception propagation out of a
failed device queue (no hang, no thread leak), and the read side's batched
delta-decode drains."""
import threading

import numpy as np
import pytest

from repro.core import lossless_batch as lb
from repro.core import pipeline as pl
from repro.core import refactor_fused as rff
from repro.core import sharded as shd
from repro.data.fields import gaussian_field

X = gaussian_field((32768,), slope=-2.0, seed=17)


def _write(dispatch_ahead, pipelined=True, chunk_elems=4096, x=X):
    pipe = pl.ChunkedRefactorPipeline(chunk_elems=chunk_elems, levels=2,
                                      pipelined=pipelined,
                                      dispatch_ahead=dispatch_ahead,
                                      use_tune_cache=False)
    return pipe, pipe.refactor(x, name="v")


# ------------------------------------------------- amortized gather counting

def test_amortized_scalar_gathers_below_one_per_chunk():
    """At depth >= 2 the drain batches must bring the amortized scalar
    gather (= batched finish) count per chunk strictly below 1: 8 chunks at
    window 2 is 4 drains, window 4 is 2."""
    for da, want_drains in [(2, 4), (4, 2)]:
        shd.STATS.reset()
        lb.STATS.reset()
        _write(da)
        st = shd.STATS.snapshot()
        assert st["chunks_finished"] == 8
        assert st["rounds"] == want_drains
        assert st["rounds"] / st["chunks_finished"] < 1.0
        # each drain is 3 host syncs flat: scalars + codec stats + payload
        assert lb.STATS.snapshot()["host_syncs"] == 3 * want_drains


def test_serial_mode_still_three_syncs_per_chunk():
    lb.STATS.reset()
    _write(2, pipelined=False)
    assert lb.STATS.snapshot()["host_syncs"] == 3 * 8


# ------------------------------------------------------ byte identity per depth

def test_async_byte_identity_at_every_depth():
    """The window depth is pure scheduling: serialized bytes at depth 1, 2
    and 4 (and in serial mode) are identical, chunk for chunk."""
    _, base = _write(2, pipelined=False)
    for da in (1, 2, 4):
        _, blobs = _write(da)
        assert blobs == base, f"depth {da} changed the serialized bytes"


def test_partial_final_window_drains_everything():
    """A chunk count that does not divide the window still drains fully
    (ceil(7/4) = 2 drains) and reproduces the serial bytes."""
    x = X[: 7 * 4096]
    shd.STATS.reset()
    _, blobs = _write(4, x=x)
    st = shd.STATS.snapshot()
    assert st["chunks_finished"] == 7 and st["rounds"] == 2
    _, base = _write(4, pipelined=False, x=x)
    assert blobs == base


# ------------------------------------------------------- failure propagation

def _threads():
    return {t for t in threading.enumerate() if t.is_alive()}


def test_dispatch_failure_propagates_and_leaks_no_threads(monkeypatch):
    before = _threads()
    boom = RuntimeError("device queue failed")

    def bad_dispatch(self, ci, chunk, name="chunk", donate=False):
        if ci == 3:
            raise boom
        return orig(self, ci, chunk, name=name, donate=donate)

    orig = shd.ShardedRefactorPlan.dispatch
    monkeypatch.setattr(shd.ShardedRefactorPlan, "dispatch", bad_dispatch)
    with pytest.raises(RuntimeError, match="device queue failed"):
        _write(2)
    # the prefetcher/serializer workers must have wound down: refactor()
    # re-raises only after both queues drain and the serializer sets done
    leaked = [t for t in _threads() - before if t.is_alive()]
    assert not leaked, f"worker threads leaked: {leaked}"


def test_finish_failure_propagates_and_leaks_no_threads(monkeypatch):
    before = _threads()

    def bad_finish(self, pendings):
        raise RuntimeError("batched drain failed")

    monkeypatch.setattr(shd.ShardedRefactorPlan, "finish_many", bad_finish)
    with pytest.raises(RuntimeError, match="batched drain failed"):
        _write(2)
    leaked = [t for t in _threads() - before if t.is_alive()]
    assert not leaked, f"worker threads leaked: {leaked}"


# -------------------------------------------------------- donation plumbing

def test_pipeline_requests_donation(monkeypatch):
    """The pipelined write owns its staged device copies, so it dispatches
    with donate=True; donation only actually rewires buffers on gpu/tpu
    (donation_supported), but the request must flow through sharded.dispatch
    regardless of backend."""
    seen = []
    orig = rff.dispatch_encode

    def spy(x, name="var", donate=False, **kw):
        seen.append(donate)
        return orig(x, name=name, donate=donate, **kw)

    monkeypatch.setattr(rff, "dispatch_encode", spy)
    _, blobs = _write(2)
    assert seen and all(seen)
    _, base = _write(2, pipelined=False)
    assert blobs == base


# --------------------------------------------------------- read-side drains

def test_read_drains_batch_delta_decodes():
    """The pipelined reader stages fetched rows and delta-decodes them in
    per-window batched drains (no per-chunk apply): 8 chunks at depth 2 on
    one shard is ceil(8/2) = 4 drains, bitwise equal to the serial reader."""
    _, blobs = _write(2)
    shd.STATS.reset()
    r = pl.ChunkedReconstructPipeline(pipelined=True, depth=2)
    y = r.reconstruct(blobs, tol=1e-4)
    assert shd.STATS.snapshot()["drains"] == 4
    ys = pl.ChunkedReconstructPipeline(pipelined=False).reconstruct(
        blobs, tol=1e-4)
    assert (y == ys).all()
    assert np.abs(y - X).max() <= 1e-4


def test_async_multi_device_byte_identity(subproc):
    """1/2/4-device async writes (depth 2 AND 4) are byte-identical to the
    single-device serial oracle, and the drain count matches
    ceil(chunks / (depth * n)) exactly."""
    subproc("""
        import numpy as np, jax
        from repro.core import pipeline as pl, sharded as shd
        x = np.random.default_rng(3).standard_normal(32768).astype(np.float32)
        base = pl.ChunkedRefactorPipeline(chunk_elems=4096, levels=2,
                                          pipelined=False,
                                          use_tune_cache=False).refactor(x)
        for n in (1, 2, 4):
            for da in (2, 4):
                shd.STATS.reset()
                blobs = pl.ChunkedRefactorPipeline(
                    chunk_elems=4096, levels=2, dispatch_ahead=da,
                    mesh=shd.make_chunk_mesh(n),
                    use_tune_cache=False).refactor(x)
                assert blobs == base, (n, da)
                st = shd.STATS.snapshot()
                assert st["rounds"] == -(-8 // (da * n)), (n, da, st)
        print("OK")
    """, n_devices=4)
