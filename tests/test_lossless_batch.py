"""Batched device-resident lossless engine: bit-exact equivalence with the
per-group codecs, O(1)-sync write path, oversize-group guards, corrupt-input
validation, and store-backed round-trips."""
import numpy as np
import pytest

from repro.core import lossless as ll
from repro.core import lossless_batch as lb
from repro.core import refactor as rf
from repro.core import retrieve as rt
from repro.data.fields import gaussian_field

RNG = np.random.default_rng(42)

GROUP_CASES = [
    (RNG.geometric(0.25, 30000) % 256).astype(np.uint8),   # skewed -> huffman
    np.zeros(40000, np.uint8),                             # degenerate runs
    RNG.integers(0, 256, 30000).astype(np.uint8),          # incompressible -> dc
    np.repeat(RNG.integers(0, 5, 60),
              RNG.integers(1, 3000, 60)).astype(np.uint8),  # long runs
    RNG.integers(0, 256, 3).astype(np.uint8),              # tiny -> dc
    np.zeros(0, np.uint8),                                 # empty group
    np.full(20000, 7, np.uint8),                           # single-symbol hist
]


# ---------------------------------------------------------------- equivalence

def test_encode_groups_bit_identical_to_per_group():
    segs_b = lb.encode_groups(GROUP_CASES)
    for data, seg_b in zip(GROUP_CASES, segs_b):
        seg_p = ll.compress_group(data)
        assert seg_b.method == seg_p.method
        assert seg_b.to_bytes() == seg_p.to_bytes()


@pytest.mark.parametrize("force", ["huffman", "rle", "dc"])
def test_encode_groups_bit_identical_forced(force):
    cfg = ll.HybridConfig(force=force)
    segs_b = lb.encode_groups(GROUP_CASES, cfg)
    for data, seg_b in zip(GROUP_CASES, segs_b):
        assert seg_b.to_bytes() == ll.compress_group(data, cfg).to_bytes()
        assert np.array_equal(lb.decode_segments([seg_b])[0], data)


def test_decode_segments_matches_per_group_decode():
    # mixed batch incl. several same-shape huffman groups (one vmapped call)
    same = [((RNG.geometric(0.2, 8192) + i) % 256).astype(np.uint8)
            for i in range(4)]
    segs = lb.encode_groups(GROUP_CASES + same,
                            ll.HybridConfig(force="huffman"))
    before = lb.STATS.snapshot()
    blobs = lb.decode_segments(segs)
    after = lb.STATS.snapshot()
    for data, seg, blob in zip(GROUP_CASES + same, segs, blobs):
        assert np.array_equal(blob, ll.decompress_group(seg))
        assert np.array_equal(blob, data)
    # the 4 same-shape groups decode through one batch, not 4 launches
    assert (after["huffman_unpack_batches"] - before["huffman_unpack_batches"]
            < sum(1 for s in segs if s.method == "huffman"))
    # one payload sync for the whole mixed batch
    assert after["host_syncs"] - before["host_syncs"] == 1


def test_device_blob_matches_numpy_view():
    # the write path's uint32 planes -> uint8 blob bitcast must reproduce
    # numpy's little-endian view byte-for-byte
    planes = RNG.integers(0, 2 ** 32, size=(6, 17), dtype=np.uint32)
    import jax.numpy as jnp
    dev = np.asarray(rf._device_bytes(jnp.asarray(planes)))
    assert np.array_equal(dev, planes.reshape(-1).view(np.uint8))


@pytest.mark.parametrize("shape,design,levels", [
    ((36, 36), "register_block", 2),
    ((33, 47), "locality", 3),
    ((2000,), "register_block", 2),
    ((), "register_block", 1),
    ((3, 0), "register_block", 2),
])
def test_refactor_batched_serialization_identical(shape, design, levels):
    n = int(np.prod(shape, dtype=int))
    x = (gaussian_field(shape, seed=3) if n > 4 else
         RNG.normal(size=shape).astype(np.float32) if n else
         np.zeros(shape, np.float32))
    rb = rf.refactor_array(x, "t", levels=levels, design=design, batched=True)
    rp = rf.refactor_array(x, "t", levels=levels, design=design, batched=False)
    assert rf.refactored_to_bytes(rb) == rf.refactored_to_bytes(rp)
    if n:
        xh, bound, _ = rt.ProgressiveReader(rb).retrieve(1e-4)
        assert np.abs(xh - x).max() <= bound


# --------------------------------------------------------------- sync budget

def test_refactor_write_path_O1_host_syncs(monkeypatch):
    """The batched write path performs a constant number of host syncs per
    chunk (1 scalar + 2 engine) regardless of pieces x groups, and never
    falls back to the per-group codecs."""
    def forbid(*a, **kw):
        raise AssertionError("per-group codec invoked on the batched path")

    monkeypatch.setattr(ll, "compress_group", forbid)
    monkeypatch.setattr(ll, "huffman_encode", forbid)
    monkeypatch.setattr(ll, "rle_encode", forbid)
    monkeypatch.setattr(ll, "dc_encode", forbid)

    x = gaussian_field((48, 48), slope=-2.0, seed=5)
    syncs = []
    for levels, group_size in [(1, 8), (3, 2)]:  # 2x4 vs 4x13 groups
        lb.STATS.reset()
        r = rf.refactor_array(x, "t", levels=levels,
                              hybrid=ll.HybridConfig(group_size=group_size))
        snap = lb.STATS.snapshot()
        syncs.append(snap["host_syncs"])
        # kernel launches are O(size buckets) = O(pieces), not O(groups)
        n_groups = sum(1 + len(p.groups) for p in r.pieces)
        launches = (snap["hist_batches"] + snap["huffman_pack_batches"]
                    + snap["rle_scan_batches"])
        assert launches < n_groups
        assert snap["hist_batches"] <= 3 * len(r.pieces)
    # host syncs constant, independent of the (pieces x groups) decomposition
    assert syncs[0] == syncs[1] == 3


# ------------------------------------------------------------ oversize guard

def test_huffman_uint32_bit_offset_guard():
    """Groups that could overflow the uint32 bit cursor are rejected with a
    clear error instead of silently wrapping the cumsum."""
    big = np.zeros(ll.MAX_GROUP_SYMS + 1, np.uint8)  # virtual alloc, cheap
    with pytest.raises(ValueError, match="MAX_GROUP_SYMS"):
        ll.huffman_encode(big)
    with pytest.raises(ValueError, match="MAX_GROUP_SYMS"):
        ll.compress_group(big)
    with pytest.raises(ValueError, match="MAX_GROUP_SYMS"):
        lb.encode_groups([big])
    # boundary: the cap itself is the largest size whose worst-case packed
    # stream still fits in uint32 bit offsets
    assert ll.MAX_GROUP_SYMS * ll.MAX_CODE_LEN < 1 << 32
    assert (ll.MAX_GROUP_SYMS + 1) * ll.MAX_CODE_LEN >= 1 << 32
    # decode side refuses corrupt oversize metadata too
    seg = ll.Segment("huffman", 0,
                     payload={"words": np.zeros(1, np.uint32),
                              "chunk_offs": np.zeros(0, np.uint32),
                              "lengths": np.zeros(256, np.uint8)},
                     meta={"n_syms": ll.MAX_GROUP_SYMS + 1, "total_bits": 0})
    with pytest.raises(ValueError, match="MAX_GROUP_SYMS"):
        ll.huffman_decode(seg)
    with pytest.raises(ValueError, match="MAX_GROUP_SYMS"):
        lb.decode_segments([seg])  # the batched read path guards too


# ------------------------------------------------------- corrupt serialization

def test_segment_from_bytes_rejects_corruption():
    seg = ll.compress_group(np.arange(100, dtype=np.uint8))
    blob = bytearray(seg.to_bytes())
    blob[0] ^= 0xFF  # clobber magic
    with pytest.raises(ValueError, match="corrupt segment"):
        ll.Segment.from_bytes(bytes(blob))
    blob2 = bytearray(seg.to_bytes())
    blob2[4] = 0x7F  # unknown method code
    with pytest.raises(ValueError, match="corrupt segment"):
        ll.Segment.from_bytes(bytes(blob2))
    # truncation (the common real corruption) is a ValueError, not a raw
    # struct.error leaking from the parser
    for cut in [1, 8, len(seg.to_bytes()) // 2]:
        with pytest.raises(ValueError, match="corrupt segment"):
            ll.Segment.from_bytes(seg.to_bytes()[:cut])
    # bad dtype chars and negative sizes are rejected, not mis-parsed
    import struct
    head = struct.pack("<IIIi", ll._MAGIC, 0, 4, 1) + struct.pack("<i", 0)
    entry = struct.pack("<i", 1) + b"r"
    with pytest.raises(ValueError, match="bad dtype"):
        ll.Segment.from_bytes(head + entry + struct.pack("<ci", b"x", 4))
    with pytest.raises(ValueError, match="negative payload size"):
        ll.Segment.from_bytes(head + entry + struct.pack("<ci", b"B", -4))


def test_refactored_from_bytes_rejects_bad_magic():
    r = rf.refactor_array(np.ones((8, 8), np.float32), "t", levels=1)
    blob = bytearray(rf.refactored_to_bytes(r))
    blob[0] ^= 0xFF
    with pytest.raises(ValueError, match="bad magic"):
        rf.refactored_from_bytes(bytes(blob))
    with pytest.raises(ValueError, match="corrupt refactored blob"):
        rf.refactored_from_bytes(bytes(blob[:3]))


# ------------------------------------------------------- store-backed round-trip

def test_store_stub_roundtrip_uses_batched_decode(tmp_path):
    from repro.store import DatasetStore, DatasetWriter, RetrievalService
    x = gaussian_field((24, 24, 24), slope=-2.0, seed=9)
    root = str(tmp_path / "store")
    with DatasetWriter(root, chunk_elems=8000) as w:
        w.write("v", x)
    lb.STATS.reset()
    svc = RetrievalService(DatasetStore.open(root))
    s = svc.open_session()
    xh, bound, fetched = s.retrieve("v", 1e-4)
    assert float(np.abs(xh - x).max()) <= bound <= 1e-4
    assert fetched > 0
    snap = lb.STATS.snapshot()
    # store-backed stub segments were decoded through the engine, batched
    assert snap["groups_decoded"] > 0
    assert snap["decode_calls"] < snap["groups_decoded"]
