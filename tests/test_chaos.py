"""Chaos suite: the full read stack under injected faults.

* Transient-only faults: every retrieval must be byte-identical to the
  fault-free oracle (retries absorb the chaos).
* Corruption mix: every request either raises a typed error or — under the
  degrade policy — returns a result whose REPORTED bound covers the true
  max error versus ground truth.  Zero silent corruption.
* Fuzz property: random bit flips / truncations across the serialized store
  (segment file AND manifest) surface as typed errors or leave reads
  byte-identical — never IndexError/struct.error, never wrong data.
"""
import os
import random
import shutil
import tempfile

import numpy as np
import pytest

from repro.core import qoi as qq
from repro.data.fields import gaussian_field
from repro.store import (DatasetStore, DatasetWriter, RetrievalService)
from repro.store import backend as bk
from repro.store import layout as lo
from repro.store import reliability as rl

TOLS = [1e-2, 1e-3, 1e-4]


@pytest.fixture(scope="module")
def field():
    return gaussian_field((24, 24, 24), slope=-2.0, seed=5)


@pytest.fixture(scope="module")
def store_dir(tmp_path_factory, field):
    root = str(tmp_path_factory.mktemp("chaos_store"))
    with DatasetWriter(root, chunk_elems=8000) as w:
        w.write("v", field)
    return root


@pytest.fixture(scope="module")
def oracle(store_dir):
    """Fault-free incremental retrieval ladder (the byte-identical target)."""
    with DatasetStore.open(store_dir) as store:
        s = RetrievalService(store).open_session()
        return {tol: tuple(s.retrieve("v", tol)[:2]) for tol in TOLS}


def chaos_store(root, degrade=False, attempts=8, **fault_kw):
    """Store whose reads run through FaultInjection + Retrying + Caching.
    The manifest is protected: manifest corruption is the fuzz test's job."""
    fault_kw.setdefault("seed", 1234)
    faults = rl.FaultConfig(protect=("manifest",), **fault_kw)
    policy = rl.RetryPolicy(attempts=attempts, base_delay_s=1e-4,
                            max_delay_s=1e-3)
    backend = bk.CachingBackend(
        rl.RetryingBackend(rl.FaultInjectionBackend(
            bk.LocalFileBackend(root), faults), policy,
            rng=random.Random(faults.seed)))
    return DatasetStore.open(root, backend=backend)


# ---------------------------------------------------------- transient-only --

def test_transient_faults_retrieve_byte_identical(store_dir, oracle):
    with chaos_store(store_dir, transient=0.05) as store:
        s = RetrievalService(store).open_session()
        for tol in TOLS:
            x, bound, _ = s.retrieve("v", tol)
            xo, bo = oracle[tol]
            assert np.array_equal(x, xo) and bound == bo
        assert s.stats.degraded_groups == 0
        # faults actually fired and retries absorbed every one of them
        retry = store.backend.inner
        assert retry.inner.stats.transient_injected > 0
        assert retry.stats.retries >= retry.inner.stats.transient_injected
        assert retry.stats.exhausted == 0


def test_transient_faults_via_env_knob(store_dir, oracle, monkeypatch):
    """REPRO_CHAOS wraps the DEFAULT store backend: the CI chaos job runs
    ordinary suites through injected faults with zero test changes."""
    monkeypatch.setenv(rl.CHAOS_ENV, "transient=0.05,seed=1234")
    with DatasetStore.open(store_dir) as store:
        assert isinstance(store.backend.inner, rl.RetryingBackend)
        s = RetrievalService(store).open_session()
        x, bound, _ = s.retrieve("v", 1e-3)
        xo, bo = oracle[1e-3]
        assert np.array_equal(x, xo) and bound == bo


def test_transient_faults_retrieve_many_and_qoi(store_dir, oracle):
    with chaos_store(store_dir, transient=0.05) as store:
        svc = RetrievalService(store)
        s1, s2 = svc.open_session(), svc.open_session()
        outs = svc.retrieve_many([(s1, "v", 1e-3), (s2, "v", 1e-2)])
        assert np.array_equal(outs[0][0], oracle[1e-3][0])
        assert np.array_equal(outs[1][0], oracle[1e-2][0])
        res = s1.retrieve_qoi(["v"], qq.V_TOTAL, tau=1.0)
        assert res.converged and res.degraded_groups == 0


# ----------------------------------------------------------- corruption mix --

def test_corruption_without_degrade_raises_typed(store_dir, oracle):
    with chaos_store(store_dir, corrupt=0.5) as store:
        s = RetrievalService(store).open_session()
        try:
            x, bound, _ = s.retrieve("v", 1e-4)
        except (rl.StoreIOError, ValueError):
            return  # typed failure is a correct outcome
        # the only acceptable success is the byte-identical one
        xo, bo = oracle[1e-4]
        assert np.array_equal(x, xo) and bound == bo


def test_corruption_with_degrade_reports_honest_bound(store_dir, field):
    with chaos_store(store_dir, corrupt=0.4) as store:
        svc = RetrievalService(store, degrade=True)
        s = svc.open_session()
        for tol in TOLS:
            x, bound, _ = s.retrieve("v", tol)
            true_err = float(np.abs(x - field).max())
            # the REPORTED bound must cover the true error even though some
            # plane groups were dropped (zero silent corruption)
            assert true_err <= bound, (tol, true_err, bound)
        vr = s.reader("v")
        assert vr.degraded_count > 0  # chaos at 40% certainly hit something
        assert s.stats.degraded_groups == vr.degraded_count
        # degradation events name the dropped (chunk, piece, group, errtype)
        assert all(e[3] in ("CorruptSegmentError", "UnreachableSegmentError",
                            "TruncatedReadError") for e in vr.degraded)


def test_truncation_with_degrade_reports_honest_bound(store_dir, field):
    with chaos_store(store_dir, truncate=0.3) as store:
        s = RetrievalService(store, degrade=True).open_session()
        x, bound, _ = s.retrieve("v", 1e-4)
        assert float(np.abs(x - field).max()) <= bound


def test_degrade_qoi_reports_unattainable_tau(store_dir, field):
    """Algorithm 3 under heavy corruption: the loop terminates at the
    degradation-raised floor with converged=False instead of spinning."""
    with chaos_store(store_dir, corrupt=0.9) as store:
        s = RetrievalService(store, degrade=True).open_session()
        res = s.retrieve_qoi(["v"], qq.V_TOTAL, tau=1e-6)
        assert not res.converged
        assert res.degraded_groups > 0
        assert res.iterations < 100  # terminated well before max_iters
        # the reported QoI error estimate is still conservative
        true_qoi_err = float(np.abs(res.values[0] ** 2 -
                                    np.asarray(field, np.float64) ** 2).max())
        assert true_qoi_err <= res.tau_estimated * (1 + 1e-6)


def test_degrade_reset_allows_recovery(store_dir, oracle):
    """reset_degraded() clears the caps: after the fault source heals, the
    same session fetches the previously dropped groups."""
    store = DatasetStore.open(store_dir)
    svc = RetrievalService(store, degrade=True)
    s = svc.open_session()
    vr = s.reader("v")
    # poison one chunk reader's piece manually (as a failed fetch would)
    r0 = vr.chunk_readers[0]
    r0.state[0].cap = 0
    r0.degraded.append((0, -1, "UnreachableSegmentError"))
    x, bound, _ = s.retrieve("v", 1e-3)
    assert bound > oracle[1e-3][1]  # degraded bound is honestly wider
    vr.reset_degraded()
    assert vr.degraded_count == 0
    # the capped groups are fetchable again: the retried request meets the
    # tolerance (the degraded pass may have over-fetched elsewhere, so the
    # exact group set — and hence the bytes — can differ from a cold session)
    x2, b2, _ = s.retrieve("v", 1e-3)
    assert b2 <= 1e-3 < bound or b2 <= oracle[1e-3][1]
    assert vr.chunk_readers[0].state[0].groups_fetched > 0
    store.close()


# ------------------------------------------------------------ fuzz property --

_FUZZ: dict = {}


def _fuzz_corpus():
    """Small store serialized into memory buffers + its fault-free oracle.
    Built once (module-lifetime); each fuzz example mutates a COPY."""
    if not _FUZZ:
        root = tempfile.mkdtemp(prefix="fuzz_store")
        try:
            f = gaussian_field((12, 12, 12), slope=-2.0, seed=3)
            with DatasetWriter(root, chunk_elems=1000) as w:
                w.write("v", f)
            buffers = {}
            for dirpath, _, files in os.walk(root):
                for name in files:
                    p = os.path.join(dirpath, name)
                    key = os.path.relpath(p, root).replace(os.sep, "/")
                    with open(p, "rb") as fh:
                        buffers[key] = fh.read()
            store = DatasetStore.open(root)
            s = RetrievalService(store).open_session()
            x, bound, _ = s.retrieve("v", 1e-4)
            store.close()
            _FUZZ.update(buffers=buffers, oracle=x.copy(), bound=bound)
        finally:
            shutil.rmtree(root, ignore_errors=True)
    return _FUZZ


def _fuzz_one(entropy: int, mode: str) -> str:
    """One fuzz example: corrupt one buffer, drive the full read path, and
    classify the outcome.  Returns the outcome label; raises (failing the
    test) on any non-typed error or silently wrong data."""
    fz = _fuzz_corpus()
    rng = random.Random(entropy)
    buffers = dict(fz["buffers"])
    key = rng.choice(sorted(buffers))
    buf = bytearray(buffers[key])
    if mode == "flip":
        pos = rng.randrange(len(buf))
        buf[pos] ^= 1 << rng.randrange(8)
        buffers[key] = bytes(buf)
    else:  # truncate
        buffers[key] = bytes(buf[:rng.randrange(len(buf))])
    try:
        store = DatasetStore.open("", backend=bk.InMemoryBackend(buffers))
        s = RetrievalService(store).open_session()
        x, bound, _ = s.retrieve("v", 1e-4)
    except (rl.StoreIOError, ValueError) as e:
        return type(e).__name__  # typed failure: correct outcome
    # success must be byte-identical — silent corruption is the one outcome
    # this whole subsystem exists to rule out
    assert np.array_equal(x, fz["oracle"]) and bound == fz["bound"], \
        f"SILENT CORRUPTION serving {key} ({mode})"
    return "identical"


def test_corruption_fuzz_property():
    from hypothesis import given, settings, strategies as st

    outcomes = []

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from(("flip", "truncate")))
    def run(entropy, mode):
        outcomes.append(_fuzz_one(entropy, mode))

    run()
    # the corpus must actually exercise both outcome classes
    assert any(o != "identical" for o in outcomes)


def test_fuzz_covers_raw_payload_flips():
    """Directed case for the known offender: a bit flip INSIDE a raw
    direct-copy ('dc' / store_raw) payload — which has no framing integrity
    of its own — must be caught by the recorded CRC instead of silently
    reconstructing wrong data."""
    fz = _fuzz_corpus()
    buffers = dict(fz["buffers"])
    man = [k for k in buffers if k.endswith("manifest.json")][0]
    seg = [k for k in buffers if k.endswith(".seg")][0]
    import json
    j = json.loads(buffers[man])
    raw_refs = [lo.GroupRef.from_json(g)
                for v in j["variables"].values() for c in v["chunks"]
                for p in c["pieces"] for g in [p["sign"]] + p["groups"]
                if str(g[2]) == "dc" or "raw" in str(g[2])]
    if not raw_refs:
        pytest.skip("corpus stored no raw-method segments")
    hits = 0
    for ref in raw_refs[:8]:
        buf = bytearray(buffers[seg])
        # flip inside the payload half of the range (past the header)
        buf[ref.offset + ref.size // 2 + ref.size // 4] ^= 0x10
        store = DatasetStore.open(
            "", backend=bk.InMemoryBackend({**buffers, seg: bytes(buf)}))
        try:
            RetrievalService(store).open_session().retrieve("v", 1e-4)
        except (rl.StoreIOError, ValueError):
            hits += 1
            continue
        # flip may land in padding a coarse retrieve never decodes — but a
        # byte INSIDE an addressed range must at minimum fail verification
        # when that exact segment is read
        with pytest.raises((rl.StoreIOError, ValueError)):
            store.read_segment("v", ref)
        hits += 1
    assert hits == len(raw_refs[:8])
