"""Store reliability layer: error taxonomy, checksums, retry/backoff/breaker,
fault injection determinism, caching-backend failure propagation."""
import json
import os
import threading
import time

import pytest

from repro.store import backend as bk
from repro.store import reliability as rl


# ------------------------------------------------------------------ helpers --

class FakeClock:
    """Deterministic monotonic clock + sleep for retry tests (no real waits)."""

    def __init__(self):
        self.t = 0.0
        self.sleeps = []

    def __call__(self):
        return self.t

    def sleep(self, s):
        self.sleeps.append(s)
        self.t += s


class ScriptedInner:
    """Inner backend that raises scripted exceptions before succeeding."""

    def __init__(self, data=b"payload", failures=()):
        self.data = data
        self.failures = list(failures)
        self.calls = 0

    def read(self, key, offset, size):
        self.calls += 1
        if self.failures:
            raise self.failures.pop(0)
        return self.data

    def size(self, key):
        self.calls += 1
        if self.failures:
            raise self.failures.pop(0)
        return len(self.data)

    def prefetch(self, key, offset, size):
        pass

    def close(self):
        pass


def retrying(inner, **policy_kw):
    clock = FakeClock()
    policy = rl.RetryPolicy(**policy_kw) if policy_kw else rl.RetryPolicy()
    b = rl.RetryingBackend(inner, policy, clock=clock, sleep=clock.sleep,
                           rng=__import__("random").Random(0))
    return b, clock


# ----------------------------------------------------------------- taxonomy --

def test_error_taxonomy_classification():
    assert rl.classify(rl.TransientFetchError("x")) == "transient"
    assert rl.classify(TimeoutError()) == "transient"
    assert rl.classify(ConnectionError()) == "transient"
    assert rl.classify(OSError(5, "EIO")) == "transient"
    assert rl.classify(rl.CorruptSegmentError("x")) == "corrupt"
    assert rl.classify(rl.TruncatedReadError("x")) == "corrupt"
    assert rl.classify(rl.FatalStoreError("x")) == "fatal"
    assert rl.classify(FileNotFoundError()) == "fatal"
    assert rl.classify(KeyError("k")) == "fatal"
    assert rl.classify(RuntimeError()) == "fatal"


def test_corrupt_is_valueerror_and_all_are_store_errors():
    # pre-checksum callers that catch ValueError on decode keep working
    assert issubclass(rl.CorruptSegmentError, ValueError)
    assert issubclass(rl.TruncatedReadError, rl.CorruptSegmentError)
    for t in (rl.TransientFetchError, rl.CorruptSegmentError,
              rl.TruncatedReadError, rl.FatalStoreError,
              rl.UnreachableSegmentError):
        assert issubclass(t, rl.StoreIOError)


def test_checksum_verify():
    blob = b"some segment bytes"
    c = rl.checksum(blob)
    rl.verify_checksum(blob, c)  # no raise
    with pytest.raises(rl.CorruptSegmentError):
        rl.verify_checksum(blob + b"x", c)
    with pytest.raises(rl.CorruptSegmentError):
        rl.verify_checksum(blob, c ^ 1)


def test_manifest_body_checksum_survives_json_roundtrip():
    body = {"v": {"shape": [3, 4], "amax": 0.25, "chunks": [[0, 10, "huff"]]}}
    c = rl.manifest_body_checksum(body)
    reparsed = json.loads(json.dumps({"variables": body, "crc32": c}))
    assert rl.manifest_body_checksum(reparsed["variables"]) == c


# -------------------------------------------------------------------- retry --

def test_retry_transient_then_success():
    inner = ScriptedInner(failures=[rl.TransientFetchError("flake"),
                                    TimeoutError()])
    b, clock = retrying(inner, attempts=4, base_delay_s=0.1, max_delay_s=1.0)
    assert b.read("k", 0, 7) == b"payload"
    assert inner.calls == 3
    assert b.stats.retries == 2
    assert b.stats.transient_errors == 2
    assert len(clock.sleeps) == 2
    # bounded exponential backoff with full jitter: attempt k's delay is in
    # [base/2, base] * 2^(k-1), capped
    assert 0.05 <= clock.sleeps[0] <= 0.1
    assert 0.1 <= clock.sleeps[1] <= 0.2


def test_retry_never_retries_corruption_or_fatal():
    for exc, kind in [(rl.CorruptSegmentError("rot"), "corrupt"),
                      (FileNotFoundError("gone"), "fatal")]:
        inner = ScriptedInner(failures=[exc])
        b, clock = retrying(inner, attempts=5)
        with pytest.raises(type(exc)):
            b.read("k", 0, 7)
        assert inner.calls == 1  # no second attempt
        assert clock.sleeps == []


def test_retry_exhaustion_raises_unreachable_with_cause():
    inner = ScriptedInner(failures=[rl.TransientFetchError(f"f{i}")
                                    for i in range(10)])
    b, _ = retrying(inner, attempts=3, base_delay_s=0.01)
    with pytest.raises(rl.UnreachableSegmentError) as ei:
        b.read("k", 0, 7)
    assert inner.calls == 3
    assert isinstance(ei.value.__cause__, rl.TransientFetchError)
    assert b.stats.exhausted == 1


def test_retry_deadline_cuts_attempts_short():
    inner = ScriptedInner(failures=[rl.TransientFetchError(f"f{i}")
                                    for i in range(100)])
    # base delay 10s vs 1s deadline: the first backoff would blow the
    # deadline, so only ONE attempt runs before UnreachableSegmentError
    b, clock = retrying(inner, attempts=50, base_delay_s=10.0,
                        max_delay_s=10.0, deadline_s=1.0)
    with pytest.raises(rl.UnreachableSegmentError):
        b.read("k", 0, 7)
    assert inner.calls == 1
    assert clock.sleeps == []


def test_circuit_breaker_opens_fast_fails_and_half_opens():
    inner = ScriptedInner(failures=[rl.TransientFetchError(f"f{i}")
                                    for i in range(100)])
    b, clock = retrying(inner, attempts=1, breaker_threshold=3,
                        breaker_reset_s=5.0)
    for _ in range(3):  # trip the breaker: 3 consecutive exhausted reads
        with pytest.raises(rl.UnreachableSegmentError):
            b.read("k", 0, 7)
    calls = inner.calls
    with pytest.raises(rl.UnreachableSegmentError):  # fast fail: no traffic
        b.read("k", 0, 7)
    assert inner.calls == calls
    assert b.stats.breaker_fast_fails == 1
    assert b.stats.breaker_opens == 1
    # other keys are unaffected: their reads still reach the inner backend
    calls = inner.calls
    with pytest.raises(rl.UnreachableSegmentError):
        b.read("other", 0, 7)  # inner is still scripted to fail
    assert inner.calls == calls + 1
    # after the reset window one probe read half-opens the circuit
    clock.t += 10.0
    inner.failures = []
    assert b.read("k", 0, 7) == b"payload"
    assert b.read("k", 0, 7) == b"payload"  # closed again


def test_retry_size_retried_prefetch_passthrough():
    inner = ScriptedInner(failures=[TimeoutError()])
    b, _ = retrying(inner)
    assert b.size("k") == 7
    b.prefetch("k", 0, 7)  # hint only: never retried, never raises
    b.close()


# --------------------------------------------------------- fault injection --

def _fault_reads(seed, n=400, **kw):
    inner = bk.InMemoryBackend({"seg": bytes(range(256)) * 16})
    fb = rl.FaultInjectionBackend(inner, rl.FaultConfig(seed=seed, **kw))
    out = []
    for i in range(n):
        off = (i * 13) % 1024
        try:
            out.append(fb.read("seg", off, 64))
        except rl.StoreIOError as e:
            out.append(type(e).__name__)
    return out, fb.stats


def test_fault_injection_deterministic_across_instances():
    a, sa = _fault_reads(seed=42, transient=0.2, corrupt=0.1)
    b, sb = _fault_reads(seed=42, transient=0.2, corrupt=0.1)
    assert a == b
    assert sa.snapshot() == sb.snapshot()
    assert sa.transient_injected > 0 and sa.corrupt_injected > 0
    c, _ = _fault_reads(seed=43, transient=0.2, corrupt=0.1)
    assert a != c  # a different seed draws a different fault pattern


def test_fault_injection_corruption_is_sticky_single_bitflip():
    inner = bk.InMemoryBackend({"seg": os.urandom(4096)})
    fb = rl.FaultInjectionBackend(inner, rl.FaultConfig(corrupt=1.0, seed=7))
    clean = inner.read("seg", 128, 256)
    r1 = fb.read("seg", 128, 256)
    r2 = fb.read("seg", 128, 256)  # a retry sees the SAME rot
    assert r1 == r2 and r1 != clean
    diff = [(i, a ^ b) for i, (a, b) in enumerate(zip(clean, r1)) if a != b]
    assert len(diff) == 1 and bin(diff[0][1]).count("1") == 1


def test_fault_injection_truncation_and_protect():
    inner = bk.InMemoryBackend({"seg": os.urandom(1024),
                                "manifest.json": b"{}" * 100})
    fb = rl.FaultInjectionBackend(
        inner, rl.FaultConfig(truncate=1.0, transient=1.0, seed=3,
                              protect=("manifest",)))
    # protected key: no transient, no truncation, byte-identical
    assert fb.read("manifest.json", 0, 50) == inner.read("manifest.json", 0, 50)
    with pytest.raises(rl.TransientFetchError):
        fb.read("seg", 0, 100)


def test_fault_injection_slow_read_sleeps():
    inner = bk.InMemoryBackend({"seg": b"x" * 64})
    fb = rl.FaultInjectionBackend(
        inner, rl.FaultConfig(slow=1.0, slow_s=0.01, seed=1))
    t0 = time.perf_counter()
    assert fb.read("seg", 0, 64) == b"x" * 64
    assert time.perf_counter() - t0 >= 0.009
    assert fb.stats.slow_injected == 1


def test_chaos_from_env_parsing():
    inner = bk.InMemoryBackend({"k": b"data"})
    assert rl.chaos_from_env(inner, env="") is inner  # unset -> identity
    wrapped = rl.chaos_from_env(inner, env="transient=0.25,seed=9,attempts=3")
    assert isinstance(wrapped, rl.RetryingBackend)
    assert isinstance(wrapped.inner, rl.FaultInjectionBackend)
    assert wrapped.inner.faults.transient == 0.25
    assert wrapped.inner.faults.seed == 9
    assert wrapped.policy.attempts == 3
    assert wrapped.read("k", 0, 4) == b"data"  # retries absorb the faults


def test_chaos_env_composes_with_retries_to_serve_identically():
    payload = os.urandom(2048)
    inner = bk.InMemoryBackend({"seg": payload})
    wrapped = rl.chaos_from_env(inner, env="transient=0.3,seed=11,attempts=8")
    for i in range(64):
        off = (i * 37) % 1024
        assert wrapped.read("seg", off, 128) == payload[off:off + 128]


# -------------------------------------------- caching backend failure paths --

class _BlockingFlaky:
    """Inner backend: first read blocks until released, then raises; later
    reads succeed.  Exercises the coalescing-under-failure path."""

    caches = False

    def __init__(self):
        self.release = threading.Event()
        self.entered = threading.Event()
        self.calls = 0
        self.fail_first = True

    def read(self, key, offset, size):
        self.calls += 1
        first = self.calls == 1
        if first:
            self.entered.set()
            self.release.wait(timeout=5.0)
            if self.fail_first:
                raise rl.TransientFetchError("flaky first read")
        return b"d" * size

    def size(self, key):
        return 1 << 20

    def prefetch(self, key, offset, size):
        pass

    def close(self):
        pass


def test_caching_backend_propagates_error_to_all_coalesced_waiters():
    inner = _BlockingFlaky()
    cb = bk.CachingBackend(inner, workers=0)
    results = []

    def reader():
        try:
            results.append(cb.read("k", 0, 8))
        except rl.TransientFetchError as e:
            results.append(type(e).__name__)

    t_owner = threading.Thread(target=reader)
    t_owner.start()
    assert inner.entered.wait(timeout=5.0)
    waiters = [threading.Thread(target=reader) for _ in range(4)]
    for t in waiters:
        t.start()
    time.sleep(0.05)  # let the waiters coalesce on the in-flight entry
    inner.release.set()
    for t in [t_owner] + waiters:
        t.join(timeout=5.0)
    # every coalesced reader saw the SAME typed error, exactly one inner read
    # happened for the failed round...
    assert results.count("TransientFetchError") >= 1
    # ...and the entry was cleared: a fresh read succeeds with a new fetch
    assert cb.read("k", 0, 8) == b"d" * 8
    assert ("k", 0, 8) not in cb._inflight


def test_caching_backend_prefetch_worker_survives_inner_failure():
    inner = _BlockingFlaky()
    inner.release.set()  # don't block; first read still raises
    cb = bk.CachingBackend(inner, workers=1)
    cb.prefetch("k", 0, 8)  # this fetch RAISES inside the worker
    deadline = time.time() + 5.0
    while cb._inflight and time.time() < deadline:
        time.sleep(0.01)
    # worker thread must still be alive and serving the queue afterwards
    cb.prefetch("k", 64, 8)
    while (("k", 64, 8) not in cb._cache) and time.time() < deadline:
        time.sleep(0.01)
    assert cb._cache.get(("k", 64, 8)) == b"d" * 8
    assert any(w.is_alive() for w in cb._workers)
    cb.close()


def test_local_file_backend_truncated_read_is_typed(tmp_path):
    p = tmp_path / "seg"
    p.write_bytes(b"0123456789")
    b = bk.LocalFileBackend(str(tmp_path))
    assert b.read("seg", 2, 5) == b"23456"
    with pytest.raises(rl.TruncatedReadError):
        b.read("seg", 5, 10)  # range runs past EOF
    b.close()


def test_in_memory_backend_truncated_read_is_typed():
    b = bk.InMemoryBackend({"seg": b"0123"})
    with pytest.raises(rl.TruncatedReadError):
        b.read("seg", 2, 10)
