"""Bitplane kernels: Pallas (interpret) vs pure-jnp ref vs numpy oracle,
swept over shapes/dtypes/designs — the portability contract is bit-exactness.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref, ops

DESIGNS = ["register_block", "locality", "shuffle"]


@pytest.mark.parametrize("design", DESIGNS)
@pytest.mark.parametrize("n", [1, 100, 4096, 5000, 12289])
@pytest.mark.parametrize("planes", [1, 7, 30, 32])
def test_ref_roundtrip(design, n, planes):
    rng = np.random.default_rng(n + planes)
    mag = rng.integers(0, 2 ** min(planes, 31), n).astype(np.uint32)
    p = ref.encode(jnp.asarray(mag), planes, design)
    assert np.array_equal(np.asarray(p), ref.encode_np(mag, planes, design))
    dec = ref.decode(p, planes, n, design)
    assert np.array_equal(np.asarray(dec), mag)


@pytest.mark.parametrize("design", DESIGNS)
@pytest.mark.parametrize("unroll", ["naive", "butterfly"])
@pytest.mark.parametrize("tiles_per_block", [1, 4])
def test_pallas_interpret_matches_ref(design, unroll, tiles_per_block):
    if design != "register_block" and unroll == "butterfly":
        pytest.skip("butterfly is the register_block unroll")
    rng = np.random.default_rng(0)
    n = 9000
    mag = rng.integers(0, 2 ** 30, n).astype(np.uint32)
    enc = ops.encode_bitplanes(jnp.asarray(mag), 30, design,
                               backend="pallas_interpret",
                               tiles_per_block=tiles_per_block, unroll=unroll)
    enc_ref = ref.encode(jnp.asarray(mag), 30, design)
    assert np.array_equal(np.asarray(enc), np.asarray(enc_ref))
    dec = ops.decode_bitplanes(enc_ref[:9], 30, n, design,
                               backend="pallas_interpret",
                               tiles_per_block=tiles_per_block, unroll=unroll)
    dec_ref = ref.decode(enc_ref[:9], 30, n, design)
    assert np.array_equal(np.asarray(dec), np.asarray(dec_ref))


@pytest.mark.parametrize("design", DESIGNS)
def test_prefix_is_truncation(design):
    """A plane prefix decodes to the magnitude with low bits zeroed."""
    rng = np.random.default_rng(7)
    n = 4500
    mag = rng.integers(0, 2 ** 30, n).astype(np.uint32)
    planes = ref.encode(jnp.asarray(mag), 30, design)
    for p in [1, 4, 17, 30]:
        dec = np.asarray(ref.decode(planes[:p], 30, n, design))
        assert np.array_equal(dec, (mag >> (30 - p)) << (30 - p)), p


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3000), st.integers(1, 31), st.integers(0, 2 ** 31 - 1))
def test_roundtrip_property(n, planes, seed):
    rng = np.random.default_rng(seed)
    mag = rng.integers(0, 2 ** planes, n, dtype=np.int64).astype(np.uint32)
    p = ref.encode(jnp.asarray(mag), planes, "register_block")
    dec = ref.decode(p, planes, n, "register_block")
    assert np.array_equal(np.asarray(dec), mag)


@pytest.mark.parametrize("design", DESIGNS)
def test_unroll_naive_butterfly_parity_interpret(design):
    """unroll='naive' and unroll='butterfly' are execution strategies, not
    formats: encode and decode must be bit-identical across all three Pallas
    designs (for locality/shuffle the knob is inert by design)."""
    rng = np.random.default_rng(13)
    n, planes = 5000, 12
    mag = rng.integers(0, 2 ** planes, n).astype(np.uint32)
    encs = {u: np.asarray(ops.encode_bitplanes(
        jnp.asarray(mag), planes, design, backend="pallas_interpret",
        unroll=u)) for u in ("naive", "butterfly")}
    assert np.array_equal(encs["naive"], encs["butterfly"])
    assert np.array_equal(encs["naive"],
                          np.asarray(ref.encode(jnp.asarray(mag), planes,
                                                design)))
    prefix = jnp.asarray(encs["naive"][:5])
    decs = {u: np.asarray(ops.decode_bitplanes(
        prefix, planes, n, design, backend="pallas_interpret", unroll=u))
        for u in ("naive", "butterfly")}
    assert np.array_equal(decs["naive"], decs["butterfly"])
    assert np.array_equal(decs["naive"],
                          np.asarray(ref.decode(prefix, planes, n, design)))


@pytest.mark.parametrize("design", DESIGNS)
@pytest.mark.parametrize("unroll", ["naive", "butterfly"])
def test_tiles_per_block_sweep_identical(design, unroll):
    """tiles_per_block only changes the grid blocking — 1/4/8 must produce
    identical planes and identical decodes."""
    rng = np.random.default_rng(29)
    n, planes = 13000, 10  # > 8 tiles, not a whole block at any sweep point
    mag = rng.integers(0, 2 ** planes, n).astype(np.uint32)
    encs = [np.asarray(ops.encode_bitplanes(
        jnp.asarray(mag), planes, design, backend="pallas_interpret",
        tiles_per_block=t, unroll=unroll)) for t in (1, 4, 8)]
    assert np.array_equal(encs[0], encs[1])
    assert np.array_equal(encs[0], encs[2])
    prefix = jnp.asarray(encs[0][:7])
    decs = [np.asarray(ops.decode_bitplanes(
        prefix, planes, n, design, backend="pallas_interpret",
        tiles_per_block=t, unroll=unroll)) for t in (1, 4, 8)]
    assert np.array_equal(decs[0], decs[1])
    assert np.array_equal(decs[0], decs[2])


def test_formats_are_distinct_but_sizes_equal():
    rng = np.random.default_rng(3)
    mag = rng.integers(0, 2 ** 30, 8192).astype(np.uint32)
    a = np.asarray(ref.encode(jnp.asarray(mag), 30, "locality"))
    b = np.asarray(ref.encode(jnp.asarray(mag), 30, "register_block"))
    assert a.shape == b.shape
    assert not np.array_equal(a, b)  # different interleave, same size
