"""Round-trip property tests for the Refactored serialization layers:
single-blob wire format, payload-free meta + segment stream, and degenerate
shapes (0-d, empty, single-element)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import lossless as ll
from repro.core import refactor as rf
from repro.core import retrieve as rt

DESIGNS = ["register_block", "locality", "shuffle"]


def _random_array(rng: np.random.Generator):
    ndim = int(rng.integers(0, 4))
    if ndim == 0:
        return rng.normal(size=()).astype(np.float32)
    # include degenerate axes (0 and 1) with small probability
    dims = [int(d) for d in rng.integers(0, 18, size=ndim)]
    if rng.uniform() < 0.7:
        dims = [max(d, 2) for d in dims]
    x = np.zeros(tuple(dims), np.float32)
    if x.size:
        x = (rng.normal(size=x.shape)
             * 10.0 ** float(rng.integers(-4, 5))).astype(np.float32)
    return x


def _assert_equivalent(r: rf.Refactored, r2: rf.Refactored):
    a, ba, _ = rt.ProgressiveReader(r).retrieve(1e-3)
    b, bb, _ = rt.ProgressiveReader(r2).retrieve(1e-3)
    assert np.array_equal(a, b)
    assert ba == bb
    assert r2.shape == r.shape and r2.levels == r.levels
    assert r2.design == r.design and r2.mag_bits == r.mag_bits
    assert r2.data_amax == r.data_amax and r2.data_range == r.data_range


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10 ** 6), st.sampled_from(DESIGNS),
       st.integers(1, 4))
def test_to_bytes_roundtrip_property(seed, design, levels):
    rng = np.random.default_rng(seed)
    x = _random_array(rng)
    r = rf.refactor_array(x, "t", levels=levels, design=design)
    r2 = rf.refactored_from_bytes(rf.refactored_to_bytes(r))
    _assert_equivalent(r, r2)
    if x.size:
        reader = rt.ProgressiveReader(r2)
        xh, bound, _ = reader.retrieve(1e-3)
        assert np.abs(xh - x).max() <= bound
        # large-amplitude data may floor above the requested tolerance
        assert bound <= max(1e-3, reader.floor_bound() * 1.001)


@pytest.mark.parametrize("shape", [(), (1,), (0,), (3, 0), (1, 1), (2,),
                                   (1, 5, 1)])
def test_degenerate_shapes_roundtrip(shape):
    rng = np.random.default_rng(1)
    n = int(np.prod(shape, dtype=int))
    x = rng.normal(size=shape).astype(np.float32) if n \
        else np.zeros(shape, np.float32)
    r = rf.refactor_array(x, "t")
    r2 = rf.refactored_from_bytes(rf.refactored_to_bytes(r))
    _assert_equivalent(r, r2)
    xh, bound, _ = rt.ProgressiveReader(r2).retrieve(1e-4)
    assert xh.shape == shape
    if n:
        assert np.abs(xh - x).max() <= bound <= 1e-4


def test_meta_plus_segments_equals_wire_format():
    """The factored layers (meta + canonical segment stream) reproduce the
    exact reader behavior of the single-blob format."""
    x = np.random.default_rng(7).normal(size=(30, 30)).astype(np.float32)
    r = rf.refactor_array(x, "t", levels=2)
    meta = rf.refactored_meta(r)
    segs = {(pi, kind, gi): ll.Segment.from_bytes(seg.to_bytes())
            for pi, kind, gi, seg in rf.iter_segments(r)}

    def lookup(pi, kind, gi):
        return segs[(pi, kind, gi)]

    r2 = rf.refactored_from_meta(meta, lookup)
    _assert_equivalent(r, r2)


def test_stub_refactored_plans_like_real():
    """Payload-free stubs (store manifests) must produce the identical greedy
    plan, since planning only reads sizes and the error model."""
    x = np.random.default_rng(3).normal(size=(24, 24)).astype(np.float32)
    r = rf.refactor_array(x, "t", levels=2)
    meta = rf.refactored_meta(r)

    def stub(pi, kind, gi):
        seg = (r.pieces[pi].sign_seg if kind == "sign"
               else r.pieces[pi].groups[gi])
        return ll.Segment(seg.method, 0, payload={},
                          meta={"stored_bytes": seg.stored_bytes,
                                **{k: v for k, v in seg.meta.items()}})

    r2 = rf.refactored_from_meta(meta, stub)
    for tol in [1e-1, 1e-3, 1e-5]:
        assert (rt.ProgressiveReader(r).plan(tol)
                == rt.ProgressiveReader(r2).plan(tol)), tol
