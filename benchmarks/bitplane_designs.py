"""Paper Fig 6/7: bitplane encode/decode throughput across parallelization
designs (locality / shuffle / register_block) and register_block unroll
variants (naive vs butterfly = the shuffle-instruction sweep analogue).

Wall-clock numbers here are the jitted pure-jnp formulation on CPU (the
container has no TPU); the *design ordering* claim is additionally checked
structurally: tests assert bit-exact portability, and the Pallas kernels
carry the VMEM-tiled TPU versions validated in interpret mode.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import timeit, row
from repro.kernels import ops, ref


def run(sizes=(1 << 20, 1 << 22)) -> list:
    lines = []
    rng = np.random.default_rng(0)
    for n in sizes:
        mag = jnp.asarray(rng.integers(0, 2 ** 23, n).astype(np.uint32))
        mb = n * 4 / 1e6
        for design in ["locality", "shuffle", "register_block"]:
            enc = jax.jit(lambda m: ops.encode_bitplanes(m, 23, design))
            planes = enc(mag)
            t = timeit(lambda: jax.block_until_ready(enc(mag)))
            lines.append(row(f"bitplane_encode_{design}_{n}", t,
                             f"{mb / 1e3 / t:.3f}GBps"))
            dec = jax.jit(lambda p: ops.decode_bitplanes(p, 23, n, design))
            dec(planes)
            t = timeit(lambda: jax.block_until_ready(dec(planes)))
            lines.append(row(f"bitplane_decode_{design}_{n}", t,
                             f"{mb / 1e3 / t:.3f}GBps"))
    # register_block unroll variants through the Pallas kernel body
    # (interpret mode on CPU: correctness + instruction-count story)
    n = 1 << 18
    mag = jnp.asarray(rng.integers(0, 2 ** 23, n).astype(np.uint32))
    for unroll in ["naive", "butterfly"]:
        enc = jax.jit(lambda m: ops.encode_bitplanes(
            m, 23, "register_block", backend="pallas_interpret", unroll=unroll))
        t = timeit(lambda: jax.block_until_ready(enc(mag)), warmup=1, iters=1)
        lines.append(row(f"bitplane_pallas_interp_{unroll}_{n}", t,
                         f"{n * 4 / 1e9 / t:.4f}GBps"))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
