"""CI perf-regression gate over benchmark JSON artifacts.

Each committed baseline (``benchmarks/baselines/<name>.json``) names one
artifact in ``out/benchmarks/`` and a list of budgets — dotted paths into
the artifact's JSON with a comparison op and a bound::

    {
      "artifact": "pipeline_overlap.json",
      "budgets": [
        {"path": "syncs_per_chunk", "op": "<=", "value": 4.0,
         "note": "3/chunk fused write + 1/chunk read"},
        {"path": "pipelined.codec.host_syncs", "op": "<=", "value": 21}
      ]
    }

Usage (the CI bench job)::

    PYTHONPATH=src python -m benchmarks.run           # writes out/benchmarks/
    PYTHONPATH=src python -m benchmarks.check_regressions

Exit status is non-zero when ANY budget is violated, an artifact is missing,
or a budget path does not resolve — a silently-skipped budget must fail the
gate, not pass it.  ``--baselines``/``--artifacts`` override the default
directories (used by the self-test in tests/test_obs.py, which doctors a
snapshot and asserts the gate trips).

Budget values are *bounds with slack* around measured reality, not
aspirations: a budget documents the regression frontier CI holds, while
targets (e.g. compression ratio >= 1.0, overlap speedup >= 1.0) are tracked
in ROADMAP.md.  Tighten a budget in the same PR that improves the metric.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Any, List, Tuple

REPO = Path(__file__).resolve().parents[1]
BASELINES = REPO / "benchmarks" / "baselines"
ARTIFACTS = REPO / "out" / "benchmarks"

OPS = {
    "<=": lambda a, b: a <= b,
    ">=": lambda a, b: a >= b,
    "<": lambda a, b: a < b,
    ">": lambda a, b: a > b,
    "==": lambda a, b: a == b,
}


def resolve(obj: Any, path: str) -> Any:
    """Walk a dotted path through dicts and lists (int segments index)."""
    cur = obj
    for seg in path.split("."):
        if isinstance(cur, list):
            cur = cur[int(seg)]
        elif isinstance(cur, dict):
            if seg not in cur:
                raise KeyError(path)
            cur = cur[seg]
        else:
            raise KeyError(path)
    return cur


def check_baseline(baseline_path: Path, artifacts_dir: Path
                   ) -> List[Tuple[bool, str]]:
    """Returns (ok, message) per budget; a missing artifact is one failure."""
    spec = json.loads(baseline_path.read_text())
    artifact = artifacts_dir / spec["artifact"]
    if not artifact.exists():
        return [(False, f"{spec['artifact']}: artifact missing "
                        f"(did the bench run?)")]
    data = json.loads(artifact.read_text())
    out: List[Tuple[bool, str]] = []
    for b in spec["budgets"]:
        path, op, bound = b["path"], b["op"], b["value"]
        tag = f"{spec['artifact']}:{path} {op} {bound}"
        try:
            got = resolve(data, path)
        except (KeyError, IndexError, ValueError, TypeError):
            out.append((False, f"{tag} — path not found in artifact"))
            continue
        if got is None or not OPS[op](got, bound):
            note = f" ({b['note']})" if b.get("note") else ""
            out.append((False, f"{tag} — got {got!r}{note}"))
        else:
            out.append((True, f"{tag} — got {got!r}"))
    return out


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baselines", type=Path, default=BASELINES)
    ap.add_argument("--artifacts", type=Path, default=ARTIFACTS)
    args = ap.parse_args(argv)
    specs = sorted(args.baselines.glob("*.json"))
    if not specs:
        print(f"check_regressions: no baselines under {args.baselines}",
              file=sys.stderr)
        return 1
    failures = 0
    for spec in specs:
        for ok, msg in check_baseline(spec, args.artifacts):
            print(("PASS  " if ok else "FAIL  ") + msg)
            failures += 0 if ok else 1
    if failures:
        print(f"check_regressions: {failures} budget(s) violated",
              file=sys.stderr)
        return 1
    print("check_regressions: all budgets hold")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
