"""Paper Fig 9: end-to-end refactor/reconstruct throughput with and without
the Fig-4 pipeline overlap."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timeit, row
from repro.core.pipeline import ChunkedRefactorPipeline, ChunkedReconstructPipeline
from repro.data.fields import gaussian_field


def run(shape=(96, 96, 96), chunk=1 << 17) -> list:
    lines = []
    x = gaussian_field(shape, slope=-2.0, seed=6)
    results = {}
    for pipelined in [False, True]:
        name = "pipelined" if pipelined else "serial"
        # warm the jit caches once (refactor AND reconstruct paths)
        wb = ChunkedRefactorPipeline(chunk_elems=chunk, pipelined=pipelined,
                                     levels=2).refactor(x, "w")
        ChunkedReconstructPipeline(pipelined=pipelined).reconstruct(wb, 1e-4)

        def go():
            p = ChunkedRefactorPipeline(chunk_elems=chunk,
                                        pipelined=pipelined, levels=2)
            blobs = p.refactor(x, "v")
            r = ChunkedReconstructPipeline(pipelined=pipelined)
            r.reconstruct(blobs, tol=1e-4)
            return p, r

        t = timeit(go, warmup=0, iters=2)
        results[name] = t
        lines.append(row(f"pipeline_{name}", t,
                         f"{x.nbytes / 1e9 / t:.4f}GBps"))
    sp = results["serial"] / results["pipelined"]
    lines.append(row("pipeline_speedup", 0.0, f"{sp:.2f}x_vs_serial"))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
