"""Paper Fig 9: end-to-end refactor/reconstruct throughput with and without
the Fig-4 pipeline overlap.

Also reports the batched codec engine's per-stage batch counts (histogram /
pack / unpack invocations and host syncs per run) and writes the result dict
to ``out/benchmarks/pipeline_overlap.json`` so CI can archive the trajectory.

Sync attribution: one traced pipelined run breaks the run's host syncs down
by originating span/label (``syncs_by_span``) — the historical "28 syncs for
7 chunks" is exactly 3/chunk on the fused write path (one ``encode.scalars``
scalar gather + the codec engine's ``codec.stats`` + ``codec.payload``) plus
1/chunk on the read path (``codec.decode``).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import codec_batches, row, timeit, write_json
from repro.core import lossless_batch as lb
from repro.core.pipeline import ChunkedRefactorPipeline, ChunkedReconstructPipeline
from repro.data.fields import gaussian_field
from repro.obs import trace as obs_trace


def run(shape=(96, 96, 96), chunk=1 << 17) -> list:
    lines = []
    x = gaussian_field(shape, slope=-2.0, seed=6)
    results = {}
    out_json = {"shape": list(shape), "chunk_elems": chunk}
    n_chunks = -(-x.size // chunk)
    # gate stability: the serial/pipelined ratio gates CI (>= 0.85), and a
    # single cold trial has swung it 0.95x..1.28x between runs on the shared
    # 1-core host.  Each mode therefore gets (a) one explicit jit-cache warm
    # run, (b) `warmup` further timed-loop warmups that absorb allocator and
    # page-cache effects, and (c) median-of-`iters` trials (timeit reports
    # the median, which ignores one slow outlier per tail).
    warmup, iters = 1, 5
    for pipelined in [False, True]:
        name = "pipelined" if pipelined else "serial"
        # warm the jit caches once (refactor AND reconstruct paths)
        wb = ChunkedRefactorPipeline(chunk_elems=chunk, pipelined=pipelined,
                                     levels=2).refactor(x, "w")
        ChunkedReconstructPipeline(pipelined=pipelined).reconstruct(wb, 1e-4)

        def go():
            p = ChunkedRefactorPipeline(chunk_elems=chunk,
                                        pipelined=pipelined, levels=2)
            blobs = p.refactor(x, "v")
            r = ChunkedReconstructPipeline(pipelined=pipelined)
            r.reconstruct(blobs, tol=1e-4)
            return p, r

        lb.STATS.reset()
        t = timeit(go, warmup=warmup, iters=iters)
        # counters accumulated over all warmup+iters identical runs ->
        # report per-call (exact: the chunking and codec decisions are
        # deterministic)
        runs = warmup + iters
        codec = {k: v // runs for k, v in lb.STATS.snapshot().items()}
        results[name] = t
        out_json[name] = {"s": t, "gbps": x.nbytes / 1e9 / t,
                          "chunks": n_chunks, "codec": codec}
        lines.append(row(f"pipeline_{name}", t,
                         f"{x.nbytes / 1e9 / t:.4f}GBps"))
        # per-stage codec batch counts: with the batched engine each chunk's
        # lossless work is a handful of wide launches, not one per group
        cb = codec_batches(codec)
        lines.append(row(
            f"pipeline_{name}_codec", 0.0,
            f"groups={codec['groups_encoded']};enc_batches={cb['enc_batches']}"
            f";dec_batches={cb['dec_batches']};host_syncs={cb['host_syncs']}"))
    sp = results["serial"] / results["pipelined"]
    out_json["speedup_vs_serial"] = sp
    lines.append(row("pipeline_speedup", 0.0, f"{sp:.2f}x_vs_serial"))

    # sync attribution: ONE traced pipelined write+read run (its own tracer,
    # so the attribution covers exactly this run, not the timed loops above)
    with obs_trace.tracing() as tr:
        p = ChunkedRefactorPipeline(chunk_elems=chunk, pipelined=True,
                                    levels=2)
        blobs = p.refactor(x, "v")
        ChunkedReconstructPipeline(pipelined=True).reconstruct(blobs, 1e-4)
    by_span = tr.attribute_events(obs_trace.EV_HOST_SYNC)
    total_syncs = sum(by_span.values())
    raw, stored = x.nbytes, sum(len(b) for b in blobs)
    out_json["syncs_by_span"] = by_span
    out_json["syncs_total"] = total_syncs
    out_json["syncs_per_chunk"] = total_syncs / n_chunks
    out_json["compression_ratio"] = raw / stored
    lines.append(row("pipeline_syncs", 0.0,
                     f"{total_syncs}syncs/{n_chunks}chunks;" +
                     ";".join(f"{k}={v}" for k, v in sorted(by_span.items()))))
    lines.append(row("pipeline_compression", 0.0,
                     f"ratio={raw / stored:.3f}"))
    write_json("pipeline_overlap", out_json)
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
