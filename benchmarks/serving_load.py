"""Serving-tier load generator: the RetrievalService under open-loop traffic.

Drives the high-concurrency serving tier (docs/serving.md) the way a real
deployment would see it, and measures what the tier is for:

  * **hit-path speedup** — a 64-session burst retrieving one hot variable,
    private per-session decode (``serving=False``) vs. the shared tier's
    plane-cache hit path.  This is the headline number: decode amortization
    across sessions.
  * **open-loop Zipf load** at several session counts — each session is a
    thread with its own pre-drawn arrival schedule (exponential
    inter-arrivals, issued on schedule regardless of completion, so queueing
    delay is *measured*, not hidden), picking variables Zipf(1.1)-skewed,
    with a mixed op profile: plain retrieves, tolerance-tightening revisits
    (a session's repeat visit to a variable steps down a tolerance ladder),
    and a fraction of QoI retrievals.  Reports p50/p99 latency from the
    *scheduled* arrival, plane-cache hit rate, coalesced-work ratio, and
    backend bytes moved.

Everything is seeded: the schedule, variable choice, and op mix are
deterministic; only thread interleaving varies run to run (which is the
point — the invariants the tier guarantees hold under ANY interleaving).

Writes ``out/benchmarks/serving_load.json`` (+ Chrome trace via the obs
scope ``run.py`` installs); CI gates budgets on it in the dedicated
``serving-load`` job and the ``bench`` job's shared regression gate.
"""
from __future__ import annotations

import shutil
import tempfile
import threading
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import row, write_json
from repro.data.fields import gaussian_field
from repro.store import (CachingBackend, DatasetStore, DatasetWriter,
                         LocalFileBackend, RetrievalService)
from repro.core import qoi as qq

#: relative-tolerance ladder a session steps down on repeat visits
TOL_LADDER = [1e-1, 1e-2, 1e-3]
ZIPF_S = 1.1
QOI_FRACTION = 0.1
REQUESTS_PER_SESSION = 5
MEAN_GAP_S = 0.05
BURST_SESSIONS = 64
SESSION_COUNTS = (8, 32, 64)


def _write_store(root: str, shape, n_vars: int, chunk_elems: int) -> List[str]:
    names = [f"v{i}" for i in range(n_vars)]
    with DatasetWriter(root, chunk_elems=chunk_elems) as w:
        for i, name in enumerate(names):
            w.write(name, gaussian_field(shape, slope=-2.0, seed=100 + i))
    return names


def _open(root: str) -> DatasetStore:
    return DatasetStore.open(root,
                             backend=CachingBackend(LocalFileBackend(root)))


def _percentiles(lat_s: List[float]) -> Dict[str, float]:
    a = np.asarray(lat_s, dtype=np.float64) * 1e3
    return {"p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "mean_ms": float(a.mean()), "max_ms": float(a.max()),
            "n": int(a.size)}


# ------------------------------------------------------------ burst speedup --

def _burst(svc: RetrievalService, var: str, tol: float, n: int
           ) -> List[float]:
    """n sessions, one barrier, one retrieve each; per-request latencies."""
    sessions = [svc.open_session() for _ in range(n)]
    lat = [0.0] * n
    errs: List[BaseException] = []
    barrier = threading.Barrier(n)

    def run_one(k: int) -> None:
        barrier.wait()
        t0 = time.perf_counter()
        try:
            sessions[k].retrieve(var, tol, relative=True)
        except BaseException as exc:  # noqa: BLE001 - fail the bench loudly
            errs.append(exc)
        lat[k] = time.perf_counter() - t0

    ts = [threading.Thread(target=run_one, args=(k,)) for k in range(n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    if errs:
        raise errs[0]
    return lat


def _measure_speedup(root: str, var: str) -> Dict[str, object]:
    tol = TOL_LADDER[1]
    # cold per-session decode: every session privately fetches + decodes
    private = RetrievalService(_open(root), serving=False)
    lat_cold = _burst(private, var, tol, BURST_SESSIONS)
    # shared hit path: one session populates the plane cache, then the
    # burst rides it — claims resolve to hits, sessions only OR-apply
    shared = RetrievalService(_open(root))
    shared.open_session().retrieve(var, tol, relative=True)
    lat_hit = _burst(shared, var, tol, BURST_SESSIONS)
    snap = shared.stats()["serving"]
    return {
        "sessions": BURST_SESSIONS, "tol": tol,
        "cold_private": _percentiles(lat_cold),
        "hit_shared": _percentiles(lat_hit),
        "speedup_mean": (float(np.mean(lat_cold))
                         / max(float(np.mean(lat_hit)), 1e-9)),
        "speedup_p99": (float(np.percentile(lat_cold, 99))
                        / max(float(np.percentile(lat_hit, 99)), 1e-9)),
        "serving": {k: snap[k] for k in
                    ("requests", "plane_hits", "coalesced", "decoded",
                     "hit_rate", "shared_ratio")},
    }


# ------------------------------------------------------- open-loop Zipf load --

def _make_schedule(rng: np.random.Generator, n_sessions: int,
                   names: List[str]) -> List[List[dict]]:
    """Pre-drawn per-session request schedules (open-loop arrivals)."""
    ranks = np.arange(1, len(names) + 1, dtype=np.float64)
    weights = ranks ** -ZIPF_S
    weights /= weights.sum()
    schedules = []
    for _ in range(n_sessions):
        t = 0.0
        reqs = []
        visits: Dict[str, int] = {}
        for _ in range(REQUESTS_PER_SESSION):
            t += float(rng.exponential(MEAN_GAP_S))
            var = names[int(rng.choice(len(names), p=weights))]
            step = visits.get(var, 0)
            visits[var] = step + 1
            # revisits tighten: the tolerance-tightening traffic shape
            tol = TOL_LADDER[min(step, len(TOL_LADDER) - 1)]
            op = "qoi" if rng.random() < QOI_FRACTION else "retrieve"
            reqs.append({"at": t, "var": var, "tol": tol, "op": op})
        schedules.append(reqs)
    return schedules


def _run_load(root: str, names: List[str], n_sessions: int, seed: int
              ) -> Dict[str, object]:
    svc = RetrievalService(_open(root))
    schedules = _make_schedule(np.random.default_rng(seed), n_sessions, names)
    lat: List[float] = []
    lat_lock = threading.Lock()
    errs: List[BaseException] = []
    barrier = threading.Barrier(n_sessions)
    ranges = {n: float(svc.store.variable(n).range) for n in names}
    amaxes = {n: float(svc.store.variable(n).amax) for n in names}

    def client(k: int) -> None:
        s = svc.open_session()
        barrier.wait()
        t0 = time.perf_counter()
        try:
            for req in schedules[k]:
                # open-loop: issue on schedule; latency counts from the
                # SCHEDULED arrival, so queueing delay is included
                delay = req["at"] - (time.perf_counter() - t0)
                if delay > 0:
                    time.sleep(delay)
                if req["op"] == "qoi":
                    tau = 0.1 * amaxes[req["var"]] * ranges[req["var"]]
                    s.retrieve_qoi([req["var"]], qq.V_TOTAL, tau)
                else:
                    s.retrieve(req["var"], req["tol"], relative=True)
                done = time.perf_counter() - t0
                with lat_lock:
                    lat.append(done - req["at"])
        except BaseException as exc:  # noqa: BLE001
            errs.append(exc)
        finally:
            svc.close_session(s)

    ts = [threading.Thread(target=client, args=(k,)) for k in range(n_sessions)]
    t_start = time.perf_counter()
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    wall = time.perf_counter() - t_start
    if errs:
        raise errs[0]
    stats = svc.stats()
    tier, be = stats["serving"], stats["backend"]
    return {
        "sessions": n_sessions,
        "requests": len(lat),
        "wall_s": wall,
        "latency": _percentiles(lat),
        "serving": {k: tier[k] for k in
                    ("requests", "plane_hits", "coalesced", "decoded",
                     "decode_rounds", "decode_batches", "hit_rate",
                     "shared_ratio", "admitted", "evictions",
                     "errors_propagated")},
        "backend": {k: be[k] for k in
                    ("fetches", "bytes_fetched", "reads", "bytes_served",
                     "hit_rate")},
    }


# --------------------------------------------------------------------- main --

def run(shape=(16, 16, 16), n_vars=6, chunk_elems=3000,
        session_counts=SESSION_COUNTS) -> list:
    lines = []
    root = tempfile.mkdtemp(prefix="serving_load_")
    try:
        names = _write_store(root, shape, n_vars, chunk_elems)
        # warmup: compile the decode/QoI kernel shapes once, OUTSIDE the
        # measured windows — the load numbers should show serving behavior,
        # not first-touch jit latency (which any long-lived service pays
        # exactly once)
        wsvc = RetrievalService(_open(root))
        ws = wsvc.open_session()
        for tol in TOL_LADDER:
            ws.retrieve(names[0], tol, relative=True)
        v0 = wsvc.store.variable(names[0])
        ws.retrieve_qoi([names[0]], qq.V_TOTAL,
                        0.1 * float(v0.amax) * float(v0.range))
        result: Dict[str, object] = {
            "shape": list(shape), "n_vars": n_vars,
            "chunk_elems": chunk_elems, "zipf_s": ZIPF_S,
            "qoi_fraction": QOI_FRACTION,
            "requests_per_session": REQUESTS_PER_SESSION,
        }

        burst = _measure_speedup(root, names[0])
        result["burst"] = burst
        lines.append(row(
            "serving_hit_path", np.mean(burst["hit_shared"]["mean_ms"]) / 1e3,
            f"speedup={burst['speedup_mean']:.2f}x"
            f";hit_rate={burst['serving']['hit_rate']:.3f}"))

        result["load"] = []
        for i, n in enumerate(session_counts):
            r = _run_load(root, names, n, seed=42 + i)
            result["load"].append(r)
            lines.append(row(
                f"serving_load_{n}", r["latency"]["p50_ms"] / 1e3,
                f"p99={r['latency']['p99_ms']:.1f}ms"
                f";hit_rate={r['serving']['hit_rate']:.3f}"
                f";shared={r['serving']['shared_ratio']:.3f}"
                f";MB={r['backend']['bytes_fetched'] / 1e6:.2f}"))

        write_json("serving_load", result)
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
