"""Store serving benchmark: the repro.store read path under load.

Measures, on a freshly written on-disk store:

  * cold vs. warm segment-cache retrieval latency (same tolerance),
  * bytes fetched vs. tolerance curve (the progressive-retrieval value prop:
    loose tolerances touch a small prefix of the store),
  * N concurrent sessions served through one RetrievalService — batched
    (``retrieve_many``, shared vmapped decode) vs. each session alone.

Emits the driver's CSV rows and writes the full result dict to
``out/benchmarks/store_serving.json`` (same out/-artifact convention as the
dry-run machinery).
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import codec_batches, row, timeit, write_json
from repro.core import lossless_batch as lb
from repro.data.fields import gaussian_field
from repro.store import (CachingBackend, DatasetStore, DatasetWriter,
                         LocalFileBackend, RetrievalService)
from repro.store import layout as lo
from repro.store import reliability as rl

TOLS = [1e-1, 1e-2, 1e-3, 1e-4, 1e-5]
N_SESSIONS = 4


def _open(root: str) -> DatasetStore:
    return DatasetStore.open(
        root, backend=CachingBackend(LocalFileBackend(root)))


def run(shape=(64, 64, 64), chunk_elems=40000) -> list:
    lines = []
    result = {"shape": list(shape), "chunk_elems": chunk_elems}
    x = gaussian_field(shape, slope=-2.0, seed=7)
    rng_ = float(x.max() - x.min())
    root = tempfile.mkdtemp(prefix="store_bench_")
    try:
        lb.STATS.reset()
        t0 = time.perf_counter()
        with DatasetWriter(root, chunk_elems=chunk_elems) as w:
            entry = w.write("v", x)
        t_write = time.perf_counter() - t0
        codec_w = lb.STATS.snapshot()
        result["write_s"] = t_write
        result["stored_bytes"] = entry.stored_bytes
        result["raw_bytes"] = int(x.nbytes)
        result["compression_ratio"] = x.nbytes / max(entry.stored_bytes, 1)
        result["codec_write"] = codec_w
        lines.append(row("store_write", t_write,
                         f"{x.nbytes / 1e9 / t_write:.4f}GBps;"
                         f"compression={result['compression_ratio']:.3f}"))
        n_chunks = -(-x.size // chunk_elems)
        cb_w = codec_batches(codec_w)
        lines.append(row(
            "store_write_codec", 0.0,
            f"groups={codec_w['groups_encoded']}"
            f";enc_batches={cb_w['enc_batches']}"
            f";syncs_per_chunk={cb_w['host_syncs'] / max(n_chunks, 1):.1f}"))

        # ---- bytes-vs-tolerance curve (one incremental session, cold) -----
        store = _open(root)
        svc = RetrievalService(store)
        s = svc.open_session()
        lb.STATS.reset()
        curve = []
        for tol in TOLS:
            xh, bound, fetched = s.retrieve("v", tol * rng_)
            err = float(np.abs(xh - x).max()) / rng_
            curve.append({"tol": tol, "bytes_total": s.bytes_fetched,
                          "bytes_delta": fetched, "rel_err": err,
                          "bound": bound})
            lines.append(row(f"store_curve_{tol:.0e}", 0.0,
                             f"bytes={s.bytes_fetched};rel_err={err:.2e}"))
        result["curve"] = curve
        result["full_fraction"] = s.bytes_fetched / max(entry.stored_bytes, 1)
        codec_r = lb.STATS.snapshot()
        result["codec_read"] = codec_r
        cb_r = codec_batches(codec_r)
        lines.append(row(
            "store_curve_codec", 0.0,
            f"groups={codec_r['groups_decoded']}"
            f";dec_batches={cb_r['dec_batches']};syncs={cb_r['host_syncs']}"))
        store.close()

        # ---- cold vs warm cache -------------------------------------------
        tol = 1e-4 * rng_
        store = _open(root)
        svc = RetrievalService(store)

        def cold():
            store.backend.drop_cache()
            svc.open_session().retrieve("v", tol)

        def warm():
            svc.open_session().retrieve("v", tol)

        t_cold = timeit(cold, warmup=1, iters=3)
        t_warm = timeit(warm, warmup=1, iters=3)
        st = store.stats().snapshot()
        result.update(cold_s=t_cold, warm_s=t_warm, backend=st)
        lines.append(row("store_cold_retrieve", t_cold,
                         f"hit_rate={st['hit_rate']:.3f}"))
        lines.append(row("store_warm_retrieve", t_warm,
                         f"speedup={t_cold / max(t_warm, 1e-9):.2f}x"))
        store.close()

        # ---- checksum verification overhead -------------------------------
        # The reliability layer's integrity cost is exactly one CRC-32 pass
        # over every stored blob (write side records, read side verifies).
        # Measure that pass DIRECTLY and gate its fraction of the measured
        # write / cold-retrieve times — stable against machine noise, unlike
        # differencing two full A/B runs whose single-trial jitter dwarfs a
        # <3% effect.
        with open(lo.segment_path(root, entry.segment_file), "rb") as f:
            seg_bytes = f.read()
        ranges = [(g.offset, g.size) for c in entry.chunks for p in c.pieces
                  for g in [p.sign] + p.groups]

        def crc_pass():
            for off, size in ranges:
                rl.checksum(seg_bytes[off:off + size])

        t_crc = timeit(crc_pass, warmup=1, iters=5)
        result["checksum"] = {
            "crc_pass_s": t_crc,
            "segments": len(ranges),
            "bytes": len(seg_bytes),
            # fraction of the measured write / cold-read times one full
            # checksum pass costs (the read path checksums the same blobs
            # the write path did, so one pass bounds either side)
            "write_overhead": t_crc / max(t_write, 1e-9),
            "read_overhead": t_crc / max(t_cold, 1e-9),
        }
        lines.append(row(
            "store_checksum_pass", t_crc,
            f"write_overhead={result['checksum']['write_overhead']:.4f}"
            f";read_overhead={result['checksum']['read_overhead']:.4f}"))

        # ---- N concurrent sessions: batched vs. one-by-one ----------------
        # fresh sessions every call: session state is incremental, so reusing
        # them would time a fully-cached no-op after the first iteration.
        store = _open(root)
        svc = RetrievalService(store)

        def serial():
            for _ in range(N_SESSIONS):
                svc.open_session().retrieve("v", tol)

        t_serial = timeit(serial, warmup=1, iters=2)

        store2 = _open(root)
        svc2 = RetrievalService(store2)

        def batched():
            svc2.retrieve_many([(svc2.open_session(), "v", tol)
                                for _ in range(N_SESSIONS)])

        t_batch = timeit(batched, warmup=1, iters=2)
        result.update(n_sessions=N_SESSIONS, sessions_serial_s=t_serial,
                      sessions_batched_s=t_batch)
        lines.append(row(f"store_sessions{N_SESSIONS}_serial", t_serial, ""))
        lines.append(row(f"store_sessions{N_SESSIONS}_batched", t_batch,
                         f"speedup={t_serial / max(t_batch, 1e-9):.2f}x"))
        store.close()
        store2.close()

        write_json("store_serving", result)
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
