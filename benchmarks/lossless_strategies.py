"""Paper Fig 8: Huffman-always vs RLE-always vs Hybrid-rc{1,2,4}:
(de)compression throughput + incremental retrieval size vs the Huffman
baseline, measured over the bitplanes of a NYX-proxy variable."""
from __future__ import annotations

import numpy as np

from benchmarks.common import timeit, row
from repro.core import lossless as ll
from repro.core import refactor as rf
from repro.core import retrieve as rt
from repro.data.fields import gaussian_field


def run(shape=(64, 64, 64)) -> list:
    lines = []
    x = gaussian_field(shape, slope=-1.8, seed=5)   # NYX-like slope
    nbytes = x.nbytes
    variants = {
        "huffman": ll.HybridConfig(force="huffman"),
        "rle": ll.HybridConfig(force="rle"),
        "hybrid_rc1": ll.HybridConfig(cr_threshold=1.0),
        "hybrid_rc2": ll.HybridConfig(cr_threshold=2.0),
        "hybrid_rc4": ll.HybridConfig(cr_threshold=4.0),
    }
    retr = {}
    for name, cfg in variants.items():
        r = rf.refactor_array(x, name, hybrid=cfg)   # warm compile
        t = timeit(lambda: rf.refactor_array(x, name, hybrid=cfg),
                   warmup=0, iters=2)
        lines.append(row(f"lossless_compress_{name}", t,
                         f"{nbytes / 1e9 / t:.4f}GBps;stored={r.stored_bytes}"))
        reader = rt.ProgressiveReader(r)
        t = timeit(lambda: rt.ProgressiveReader(r).retrieve(1e-4),
                   warmup=1, iters=2)
        _, _, _ = reader.retrieve(1e-4)
        retr[name] = reader.total_bytes_fetched
        lines.append(row(f"lossless_decompress_{name}", t,
                         f"{nbytes / 1e9 / t:.4f}GBps;"
                         f"fetched={reader.total_bytes_fetched}"))
    base = retr["huffman"]
    for name, b in retr.items():
        lines.append(row(f"lossless_retrieval_overhead_{name}", 0.0,
                         f"+{100 * (b - base) / base:.1f}%_vs_huffman"))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
