"""Roofline analysis of the fused write program (feeds the autotuner).

Per (shape x design) cell, lower the fused one-dispatch encode program
(``core.refactor_fused.fused_encode_plan``), extract per-op FLOPs / HBM
bytes / collective wire bytes from the optimized HLO
(``launch.hlo_analysis``), and score the terms against hardware peaks::

  compute term    = flops / peak_flops
  memory term     = hbm_bytes / hbm_bw
  collective term = wire_bytes / link_bw

The peaks are imported from ``repro.tune.cost`` (single source of truth:
this artifact and the tuner's cost model can never disagree; the TPU row is
the v5e-class 197 TFLOP/s / 819 GB/s / 50 GB/s-link chip).  Each cell also
runs one measured probe write, so the artifact records the model's
calibration quality (``model_fraction`` = calibrated prediction / measured)
— the honesty check behind ``docs/autotune.md``'s cost-model section.

Emits CSV rows and writes ``out/benchmarks/roofline.json`` (CI artifact,
budget-gated by ``benchmarks/check_regressions.py``).
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import row, write_json
from repro.tune import cost as tc
from repro.tune import search as ts
from repro.tune.config import DEFAULT_CONFIG

# re-exported for backward compatibility: these used to live here; the
# canonical definitions moved into the tuner's cost model
PEAK_FLOPS = tc.PEAK_FLOPS
HBM_BW = tc.HBM_BW
LINK_BW = tc.LINK_BW

SHAPES = [(1 << 14,), (1 << 16,)]
LEVELS = 3


def roofline_cells(shapes=SHAPES, levels: int = LEVELS) -> List[Dict]:
    """One cell per (shape x bitplane design): HLO-derived roofline terms
    plus a measured probe of the same program."""
    peaks = tc.platform_peaks()
    cells: List[Dict] = []
    for shape in shapes:
        model = tc.CostModel(shape, levels)
        x = ts._probe_chunk(shape, "float32")
        # calibrate the model scale on the default design's measured probe;
        # the other designs then test how well the model transfers
        default = DEFAULT_CONFIG
        t_default = ts._measure_write(x, default, levels)
        model.calibrate(default, t_default)
        for design in ts.DESIGNS:
            cfg = default.replace(design=design)
            c = model.cost(cfg)
            t_c = c.flops / peaks.flops
            t_m = c.hbm_bytes / peaks.hbm_bw
            t_x = c.wire_bytes / peaks.link_bw
            dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
            measured = (t_default if design == default.design
                        else ts._measure_write(x, cfg, levels))
            predicted = model.score(cfg)
            cells.append({
                "shape": list(shape), "levels": levels, "design": design,
                "flops": c.flops, "hbm_bytes": c.hbm_bytes,
                "wire_bytes": c.wire_bytes,
                "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
                "dominant": dom[1], "bound_s": dom[0],
                "measured_s": measured, "predicted_s": predicted,
                "model_fraction": predicted / max(measured, 1e-12),
                "model_scale": model.scale,
            })
    return cells


def run() -> List[str]:
    import jax

    cells = roofline_cells()
    peaks = tc.platform_peaks()
    # calibrated section: nominal peaks / fitted scale = the rates this
    # machine actually sustained on the probes.  ``tune.cost.platform_peaks``
    # reads it back on later runs, so the tuner's cost model starts from the
    # machine, not the spec sheet.  (Fixed point: effective = cost/measured
    # regardless of which peaks scored the probes, so re-running against an
    # existing artifact does not drift.)  Median scale across cells resists
    # one noisy probe.
    scales = sorted(c["model_scale"] for c in cells)
    scale = scales[len(scales) // 2] if scales else 1.0
    result = {
        "peaks": {"flops": peaks.flops, "hbm_bw": peaks.hbm_bw,
                  "link_bw": peaks.link_bw},
        "nominal_tpu": {"flops": PEAK_FLOPS, "hbm_bw": HBM_BW,
                        "link_bw": LINK_BW},
        "calibrated": {
            "platform": jax.default_backend(), "scale": scale,
            "flops": peaks.flops / scale, "hbm_bw": peaks.hbm_bw / scale,
            "link_bw": peaks.link_bw / scale,
        },
        "cells": cells,
        # CI acceptance: every cell's HLO was analyzed.  The memory term is
        # the load-bearing one — the encode chain is bitwise ops, so HLO
        # FLOP counts are legitimately zero on some cells.
        "all_cells_analyzed": all(c["hbm_bytes"] > 0 for c in cells),
    }
    write_json("roofline", result)
    lines = []
    for c in cells:
        n = c["shape"][0]
        lines.append(row(
            f"roofline_fused_{n}_{c['design']}", c["measured_s"],
            f"dom={c['dominant']};bound_us={c['bound_s'] * 1e6:.1f};"
            f"model_frac={c['model_fraction']:.2f}"))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
