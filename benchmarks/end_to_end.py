"""Paper Fig 11: HP-MDR vs progressive baselines across error tolerances —
refactor throughput and incremental retrieval size.

Baselines (implemented, not stubbed):
  * mdr_cpu      — the classic MDR formulation: same decomposition, but
                   scalar (numpy, per-bit loop) bitplane encoding + zlib-like
                   entropy stage, i.e. the 'most compatible processor' path
                   the paper says users are forced into.
  * multi_comp   — Magri/Lindstrom-style multi-component residual compressor:
                   iteratively quantize-and-zstd the residual at a decaying
                   error bound; retrieval fetches components until the bound
                   is met (uses the installed zstandard, an off-the-shelf
                   lossless backend as in [31]).
"""
from __future__ import annotations

import io
import time

import numpy as np
import zstandard

from benchmarks.common import timeit, row
from repro.core import refactor as rf
from repro.core import retrieve as rt
from repro.data.fields import gaussian_field

TOLS = [1e-1, 1e-2, 1e-3, 1e-4, 1e-5, 1e-6]


# ------------------------------------------------------ baseline: mdr_cpu --

def mdr_cpu_refactor(x: np.ndarray):
    """Scalar bitplane encoding (numpy bit loop) + zlib-ish lossless."""
    import zlib
    flat = x.reshape(-1)
    amax = np.abs(flat).max() + 1e-30
    e = int(np.floor(np.log2(amax))) + 1   # frexp convention: amax <= 2**e
    scale = 2.0 ** (23 - e)
    q = np.round(flat * scale).astype(np.int64)
    sign = (q < 0).astype(np.uint8)
    mag = np.abs(q).astype(np.uint32)
    planes = []
    for b in range(22, -1, -1):
        bits = ((mag >> b) & 1).astype(np.uint8)
        planes.append(zlib.compress(np.packbits(bits).tobytes(), 1))
    return {"e": e, "sign": zlib.compress(np.packbits(sign).tobytes(), 1),
            "planes": planes, "n": flat.size, "shape": x.shape}


def mdr_cpu_retrieve(r, tol: float):
    import zlib
    scale = 2.0 ** (23 - r["e"])
    need = max(min(int(np.ceil(23 - np.log2(max(tol, 1e-30) * scale))), 23), 1)
    n = r["n"]
    mag = np.zeros(n, np.uint32)
    fetched = len(r["sign"])
    sign = np.unpackbits(np.frombuffer(zlib.decompress(r["sign"]), np.uint8))[:n]
    for j in range(need):
        blob = r["planes"][j]
        fetched += len(blob)
        bits = np.unpackbits(np.frombuffer(zlib.decompress(blob), np.uint8))[:n]
        mag |= bits.astype(np.uint32) << (22 - j)
    val = mag.astype(np.float64) / scale
    out = np.where(sign > 0, -val, val).astype(np.float32)
    return out.reshape(r["shape"]), fetched


# --------------------------------------------------- baseline: multi_comp --

def multi_comp_refactor(x: np.ndarray, tols=TOLS):
    comps = []
    resid = x.astype(np.float32).copy()
    rng_ = float(x.max() - x.min() + 1e-30)
    for tol in tols:
        eb = tol * rng_ if tol < 1 else tol
        q = np.round(resid / (2 * eb)).astype(np.int32)
        comps.append((eb, zstandard.compress(q.tobytes(), 3)))
        resid = resid - q.astype(np.float32) * (2 * eb)
    return {"comps": comps, "shape": x.shape}


def multi_comp_retrieve(r, tol: float):
    out = np.zeros(r["shape"], np.float32)
    fetched = 0
    for eb, blob in r["comps"]:
        fetched += len(blob)
        q = np.frombuffer(zstandard.decompress(blob),
                          np.int32).reshape(r["shape"])
        out = out + q.astype(np.float32) * (2 * eb)
        if eb <= tol:
            break
    return out, fetched


def run(shape=(64, 64, 64)) -> list:
    lines = []
    x = gaussian_field(shape, slope=-2.0, seed=7)
    rng_ = float(x.max() - x.min())

    # HP-MDR
    r = rf.refactor_array(x, "v")  # warm
    t = timeit(lambda: rf.refactor_array(x, "v"), warmup=0, iters=2)
    lines.append(row("e2e_refactor_hpmdr", t, f"{x.nbytes / 1e9 / t:.4f}GBps"))
    reader = rt.ProgressiveReader(r)
    for tol in TOLS:
        xh, bound, _ = reader.retrieve(tol * rng_)
        err = np.abs(xh - x).max() / rng_
        lines.append(row(f"e2e_retrieve_hpmdr_{tol:.0e}", 0.0,
                         f"bytes={reader.total_bytes_fetched};rel_err={err:.2e}"))

    # mdr_cpu baseline
    t = timeit(lambda: mdr_cpu_refactor(x), warmup=0, iters=1)
    lines.append(row("e2e_refactor_mdr_cpu", t, f"{x.nbytes / 1e9 / t:.4f}GBps"))
    rc = mdr_cpu_refactor(x)
    for tol in TOLS:
        xh, fetched = mdr_cpu_retrieve(rc, tol * rng_)
        err = np.abs(xh - x).max() / rng_
        lines.append(row(f"e2e_retrieve_mdr_cpu_{tol:.0e}", 0.0,
                         f"bytes={fetched};rel_err={err:.2e}"))

    # multi-component baseline
    t = timeit(lambda: multi_comp_refactor(x), warmup=0, iters=1)
    lines.append(row("e2e_refactor_multi_comp", t,
                     f"{x.nbytes / 1e9 / t:.4f}GBps"))
    rm = multi_comp_refactor(x)
    for tol in TOLS:
        xh, fetched = multi_comp_retrieve(rm, tol)
        err = np.abs(xh - x).max() / rng_
        lines.append(row(f"e2e_retrieve_multi_comp_{tol:.0e}", 0.0,
                         f"bytes={fetched};rel_err={err:.2e}"))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
