"""Paper Tables 2/3 + Figs 12/13/14: QoI-controlled progressive retrieval.

* Tables 2/3: bitrates of CP / MA / MAPE(c=2) / MAPE(c=10) across requested
  V_total tolerances, on NYX-proxy and mini-JHTDB-proxy velocity fields.
* Fig 12/14: retrieval kernel throughput per method (and multi-device).
* Fig 13: guarantee chain  actual <= estimated <= requested.
* Incremental read path: per-Algorithm-3-iteration plane bytes actually
  decoded by the device-resident engine (delta) vs. the from-scratch
  full-decode baseline — iterations after the first should delta-decode
  strictly fewer bytes than a full decode of their state.

Emits the driver's CSV rows and writes the full result dict to
``out/benchmarks/qoi_benchmarks.json`` (same out/-artifact convention as
``pipeline_overlap`` / ``store_serving``).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row, write_json
from repro.core import qoi as qq
from repro.core import reconstruct as rcn
from repro.core import refactor as rf
from repro.core import retrieve as rt
from repro.data.fields import velocity_field

TAUS = [1e-1, 5e-2, 1e-2, 5e-3, 1e-3, 5e-4, 1e-4, 5e-5]
METHODS = [("cp", {}), ("ma", {}), ("mape_c2", {"c": 2.0}),
           ("mape_c10", {"c": 10.0})]


def _refs(shape, seed, slope):
    vs = list(velocity_field(shape, seed=seed, slope=slope))
    return vs, [rf.refactor_array(v, f"v{i}") for i, v in enumerate(vs)]


def run(shape=(40, 40, 40)) -> list:
    lines = []
    result = {"shape": list(shape), "taus": TAUS, "runs": []}
    for ds_name, slope, seed in [("nyx", -1.8, 21), ("jhtdb", -5 / 3, 22)]:
        vs, refs = _refs(shape, seed, slope)
        truth = sum(v ** 2 for v in vs)
        for mname, kw in METHODS:
            method = "mape" if mname.startswith("mape") else mname
            for tau in TAUS:
                readers = [rt.ProgressiveReader(r) for r in refs]
                rcn.STATS.reset()
                t0 = time.perf_counter()
                res = qq.progressive_qoi_retrieve(readers, qq.V_TOTAL, tau,
                                                  method=method, **kw)
                dt = time.perf_counter() - t0
                actual = float(np.abs(sum(v ** 2 for v in res.values)
                                      - truth).max())
                ok = actual <= res.tau_estimated <= tau
                # the incremental-engine value prop: every iteration after
                # the first decodes only its delta plane bytes, against a
                # baseline that re-decodes the whole fetched state
                delta_after_first = sum(
                    it["delta_plane_bytes"] for it in res.per_iteration[1:])
                full_after_first = sum(
                    it["full_plane_bytes"] for it in res.per_iteration[1:])
                result["runs"].append({
                    "dataset": ds_name, "method": mname, "tau": tau,
                    "seconds": dt, "bitrate": res.bitrate,
                    "iterations": res.iterations,
                    "bytes_fetched": res.bytes_fetched,
                    "guarantee_ok": ok, "actual": actual,
                    "estimated": res.tau_estimated,
                    "per_iteration": res.per_iteration,
                    "delta_plane_bytes_after_first": delta_after_first,
                    "full_plane_bytes_after_first": full_after_first,
                    "engine": rcn.STATS.snapshot(),
                })
                lines.append(row(
                    f"qoi_{ds_name}_{mname}_{tau:.0e}", dt,
                    f"bitrate={res.bitrate:.2f};iters={res.iterations};"
                    f"tput={3 * vs[0].nbytes / 1e9 / dt:.4f}GBps;"
                    f"guarantee={'OK' if ok else 'VIOLATED'};"
                    f"actual={actual:.2e};est={res.tau_estimated:.2e};"
                    f"delta_bytes={delta_after_first};"
                    f"full_bytes={full_after_first}"))
    write_json("qoi_benchmarks", result)
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
