"""Paper Tables 2/3 + Figs 12/13/14: QoI-controlled progressive retrieval.

* Tables 2/3: bitrates of CP / MA / MAPE(c=2) / MAPE(c=10) across requested
  V_total tolerances, on NYX-proxy and mini-JHTDB-proxy velocity fields.
* Fig 12/14: retrieval kernel throughput per method (and multi-device).
* Fig 13: guarantee chain  actual <= estimated <= requested.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row
from repro.core import qoi as qq
from repro.core import refactor as rf
from repro.core import retrieve as rt
from repro.data.fields import velocity_field

TAUS = [1e-1, 5e-2, 1e-2, 5e-3, 1e-3, 5e-4, 1e-4, 5e-5]
METHODS = [("cp", {}), ("ma", {}), ("mape_c2", {"c": 2.0}),
           ("mape_c10", {"c": 10.0})]


def _refs(shape, seed, slope):
    vs = list(velocity_field(shape, seed=seed, slope=slope))
    return vs, [rf.refactor_array(v, f"v{i}") for i, v in enumerate(vs)]


def run(shape=(40, 40, 40)) -> list:
    lines = []
    for ds_name, slope, seed in [("nyx", -1.8, 21), ("jhtdb", -5 / 3, 22)]:
        vs, refs = _refs(shape, seed, slope)
        truth = sum(v ** 2 for v in vs)
        for mname, kw in METHODS:
            method = "mape" if mname.startswith("mape") else mname
            for tau in TAUS:
                readers = [rt.ProgressiveReader(r) for r in refs]
                t0 = time.perf_counter()
                res = qq.progressive_qoi_retrieve(readers, qq.V_TOTAL, tau,
                                                  method=method, **kw)
                dt = time.perf_counter() - t0
                actual = float(np.abs(sum(v ** 2 for v in res.values)
                                      - truth).max())
                ok = actual <= res.tau_estimated <= tau
                lines.append(row(
                    f"qoi_{ds_name}_{mname}_{tau:.0e}", dt,
                    f"bitrate={res.bitrate:.2f};iters={res.iterations};"
                    f"tput={3 * vs[0].nbytes / 1e9 / dt:.4f}GBps;"
                    f"guarantee={'OK' if ok else 'VIOLATED'};"
                    f"actual={actual:.2e};est={res.tau_estimated:.2e}"))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
