"""Autotuner smoke benchmark (the CI autotune job).

Exercises the full ``repro.tune`` loop on one small (shape, dtype) on the
host backend and writes ``out/benchmarks/autotune_smoke.json`` with the
properties the baseline gates:

  * first run (``force=True``) performs a real search: candidates scored
    through the HLO roofline model, measured probes run, winner stored in
    the on-disk cache (``out/tune/``);
  * second run is a CACHE HIT: the winner is replayed with NO re-search —
    ``search.STATS.searches`` must not move and ``tune_s`` collapses;
  * tuner overhead is budgeted against the default-config write time
    (``tune_overhead_ratio``, gated by check_regressions);
  * the winner can only tie or beat the default on the probe workload
    (``probe_speedup >= 1.0`` — the measured-best-of-probes rule);
  * a store written afterwards picks the cached winner up by default
    (``DatasetWriter`` -> ``ChunkedRefactorPipeline`` tune-cache consult),
    records it as the variable's manifest ``plan``, and round-trips through
    ``RetrievalService`` replaying that plan.

The shape is deliberately distinct from every other benchmark's chunk size
so its cache entries cannot collide with theirs.
"""
from __future__ import annotations

import tempfile

import numpy as np

from benchmarks.common import row, write_json

SHAPE = (24576,)
DTYPE = "float32"
TOL = 1e-3


def run() -> list:
    from repro.core import decompose as dc
    from repro.store.layout import DatasetStore
    from repro.store.service import RetrievalService
    from repro.store.writer import DatasetWriter
    from repro.tune import cache as tcache
    from repro.tune import search as tsearch
    from repro.tune.config import DEFAULT_CONFIG
    from repro.tune.search import _measure_write, _probe_chunk, tune

    levels = dc.num_levels(SHAPE)
    x = _probe_chunk(SHAPE, DTYPE)

    # budget denominator: one default-config write of the same chunk
    default_write_s = _measure_write(x, DEFAULT_CONFIG, levels)

    s0 = tsearch.STATS.snapshot()
    r1 = tune(SHAPE, dtype=DTYPE, levels=levels, probes=2, force=True)
    s1 = tsearch.STATS.snapshot()
    r2 = tune(SHAPE, dtype=DTYPE, levels=levels)
    s2 = tsearch.STATS.snapshot()

    default_probe_s = r1.probes[0][1] if r1.probes else float("nan")
    winner_probe_s = (min(s for _, s in r1.probes)
                      if r1.probes else float("nan"))

    # the cached winner is consulted by DatasetWriter by default: the store's
    # manifest plan must replay it, and the store must round-trip through the
    # retrieval service at the requested tolerance
    data = x.reshape(-1)
    with tempfile.TemporaryDirectory() as root:
        with DatasetWriter(root, chunk_elems=SHAPE[0], levels=levels) as w:
            entry = w.write("v", data)
        plan = dict(entry.plan or {})
        store = DatasetStore.open(root)
        xh, bound, fetched = (RetrievalService(store).open_session()
                              .retrieve("v", TOL))
        err = float(np.abs(xh.reshape(-1) - data).max())
        store.close()

    result = {
        "shape": list(SHAPE), "dtype": DTYPE, "levels": levels,
        "default_write_s": default_write_s,
        "first_run": {
            "cache_hit": r1.cache_hit,
            "tune_s": r1.tune_s,
            "searches": s1["searches"] - s0["searches"],
            "candidates_scored": s1["candidates_scored"]
            - s0["candidates_scored"],
            "probes_run": s1["probes_run"] - s0["probes_run"],
        },
        "second_run": {
            "cache_hit": r2.cache_hit,
            "tune_s": r2.tune_s,
            "searches_delta": s2["searches"] - s1["searches"],
            "probes_delta": s2["probes_run"] - s1["probes_run"],
            "config_identical": r2.config == r1.config,
        },
        "tune_overhead_ratio": r1.tune_s / max(default_write_s, 1e-12),
        "tuned_config": r1.config.to_json(),
        "default_probe_s": default_probe_s,
        "winner_probe_s": winner_probe_s,
        # measured-best-of-probes rule: tuned can only tie or beat default
        "probe_speedup": default_probe_s / max(winner_probe_s, 1e-12),
        "cache_stats": tcache.STATS.snapshot(),
        "store": {
            "plan_recorded": bool(plan),
            "plan_matches_winner": all(
                plan.get(k) == v for k, v in r1.config.to_json().items()
                if k in ("design", "tiles_per_block", "unroll", "group_size")),
            "bytes_fetched": int(fetched),
            "bound": float(bound),
            "max_err": err,
            "roundtrip_ok": err <= TOL,
        },
    }
    write_json("autotune_smoke", result)
    return [
        row("autotune_first_run", r1.tune_s,
            f"candidates={result['first_run']['candidates_scored']};"
            f"probes={result['first_run']['probes_run']};"
            f"overhead={result['tune_overhead_ratio']:.0f}x_default_write"),
        row("autotune_second_run", r2.tune_s,
            f"cache_hit={r2.cache_hit};"
            f"searches_delta={result['second_run']['searches_delta']}"),
        row("autotune_probe_speedup", winner_probe_s,
            f"speedup={result['probe_speedup']:.3f};"
            f"design={r1.config.design};group={r1.config.group_size}"),
        row("autotune_store_replay", result['store']['max_err'],
            f"plan_matches={result['store']['plan_matches_winner']};"
            f"roundtrip_ok={result['store']['roundtrip_ok']}"),
    ]


if __name__ == "__main__":
    print("\n".join(run()))
