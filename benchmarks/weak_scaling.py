"""Paper Fig 10: weak-scaling of refactoring across devices.

Each (host) device refactors its own shard — embarrassingly parallel, as in
the paper's multi-GPU runs.  Runs subprocesses with 1/2/4/8 host devices and
a fixed per-device workload; reports parallel efficiency vs 1 device.
On 1 physical core the host devices timeshare, so the structural efficiency
is what the assertion targets (the paper reports 89-95% on real GPUs).
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

from benchmarks.common import row

_SCRIPT = r"""
import time
import numpy as np, jax, jax.numpy as jnp
from repro.kernels import ref
n_dev = len(jax.devices())
per_dev = 1 << 20
x = jnp.asarray(np.random.default_rng(0).integers(0, 2**23, (n_dev, per_dev)).astype(np.uint32))
enc = jax.pmap(lambda m: ref.encode(m, 23, "register_block"))
jax.block_until_ready(enc(x))
t0 = time.perf_counter()
for _ in range(3):
    jax.block_until_ready(enc(x))
dt = (time.perf_counter() - t0) / 3
print(f"RESULT {n_dev} {dt:.6f} {n_dev * per_dev * 4 / dt / 1e9:.4f}")
"""


def run() -> list:
    lines = []
    repo = Path(__file__).resolve().parents[1]
    base = None
    for n in [1, 2, 4, 8]:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["PYTHONPATH"] = str(repo / "src")
        r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                           capture_output=True, text=True, timeout=600)
        out = [l for l in r.stdout.splitlines() if l.startswith("RESULT")]
        if not out:
            lines.append(row(f"weak_scaling_{n}dev", 0.0, "FAILED"))
            continue
        _, nd, dt, gbps = out[0].split()
        dt = float(dt)
        if base is None:
            base = dt
        # this container has ONE physical core timesharing the host devices:
        # the structural (parallel-overhead) efficiency compares against the
        # core-serialized ideal n*base, not the real-hardware ideal (=base).
        eff = n * base / dt
        lines.append(row(f"weak_scaling_{n}dev", dt,
                         f"{gbps}GBps;core_serialized_efficiency={eff:.2f}"))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
