"""Paper Fig 10: weak-scaling of the REAL sharded write path across devices.

Each (host) device owns a round-robin shard of the chunks and runs the full
fused refactor chain — decompose -> quantize -> bitplane encode -> lossless
-> serialize — through ``ChunkedRefactorPipeline(mesh=...)``, exactly the
path ``store.DatasetWriter`` drives (not just the raw bitplane kernel).
Per-device workload is fixed (``CHUNKS_PER_DEV`` chunks of ``CHUNK_ELEMS``),
so ideal weak scaling keeps wall time flat as devices grow.

Host devices timeshare the container's few physical cores, so two numbers
are reported per device count n:

  ``weak_efficiency``     = t_1dev / t_n — the paper's weak-scaling metric
                            (ideal 1.0, only reachable while n <= cores;
                            the paper reports 89-95% on real GPUs);
  ``serialized_speedup``  = n * t_1dev / t_n — speedup over running the n
                            shards back-to-back (ideal min(n, cores)).
                            This isolates the sharding layer's overhead
                            (placement, per-device dispatch, scalar
                            gathers), which is what can regress in CI.

Writes ``out/benchmarks/weak_scaling.json`` with per-device-count
throughput and efficiency (the CI bench artifact).  ``run(devices=N)``
narrows the matrix to {1, N} (the ``benchmarks.run --devices`` knob).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path
from typing import List, Optional

from benchmarks.common import row, write_json

CHUNK_ELEMS = 1 << 16
CHUNKS_PER_DEV = 4

_SCRIPT = rf"""
import json, time
import numpy as np, jax
from repro.core import pipeline as pl
from repro.core import sharded as shd

n_dev = len(jax.devices())
chunk_elems = {CHUNK_ELEMS}
n = n_dev * {CHUNKS_PER_DEV} * chunk_elems
x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
mesh = shd.make_chunk_mesh(n_dev)

def write():
    pipe = pl.ChunkedRefactorPipeline(chunk_elems=chunk_elems, levels=2,
                                      mesh=mesh)
    pipe.refactor(x, name="v")
    return pipe

write()  # warm the jit caches (fused plan compile is amortized in practice)
ts = []
for _ in range(3):
    t0 = time.perf_counter()
    pipe = write()
    ts.append(time.perf_counter() - t0)
dt = sorted(ts)[1]  # median of 3: single samples are too noisy on shared CI

print("RESULT " + json.dumps({{
    "devices": n_dev, "wall_s": dt, "chunks": pipe.stats.chunks,
    "bytes_in": pipe.stats.bytes_in, "bytes_out": pipe.stats.bytes_out,
    "compression_ratio": pipe.stats.bytes_in / max(pipe.stats.bytes_out, 1),
    "gbps": pipe.stats.bytes_in / dt / 1e9}}))
"""


def _one(n_dev: int, repo: Path) -> Optional[dict]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = str(repo / "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    out = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")]
    if r.returncode != 0 or not out:
        sys.stderr.write(r.stderr[-2000:])
        return None
    return json.loads(out[0][len("RESULT "):])


def run(devices: Optional[int] = None) -> List[str]:
    counts = [1, 2, 4, 8] if devices is None else sorted({1, int(devices)})
    repo = Path(__file__).resolve().parents[1]
    lines, results, base = [], [], None
    for n in counts:
        res = _one(n, repo)
        if res is None:
            lines.append(row(f"weak_scaling_{n}dev", 0.0, "FAILED"))
            continue
        if n == 1:
            base = res["wall_s"]
        # both ratios are only meaningful against the 1-device baseline: if
        # that run FAILED, later rows report no_baseline instead of a bogus
        # self-referential ratio
        if base is None:
            res["weak_efficiency"] = res["serialized_speedup"] = None
            derived = f"{res['gbps']:.4f}GBps;no_baseline"
        else:
            res["weak_efficiency"] = base / res["wall_s"]
            res["serialized_speedup"] = n * base / res["wall_s"]
            derived = (f"{res['gbps']:.4f}GBps;"
                       f"weak_efficiency={res['weak_efficiency']:.2f};"
                       f"serialized_speedup={res['serialized_speedup']:.2f};"
                       f"compression={res['compression_ratio']:.3f}")
        results.append(res)
        lines.append(row(f"weak_scaling_{n}dev", res["wall_s"], derived))
    write_json("weak_scaling", {
        "bench": "weak_scaling", "path": "ChunkedRefactorPipeline(mesh=...)",
        "chunk_elems": CHUNK_ELEMS, "chunks_per_device": CHUNKS_PER_DEV,
        "host_cores": os.cpu_count(),
        "results": results})
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
