"""Paper Fig 10: weak-scaling of the REAL sharded write path across devices.

Each (host) device owns a round-robin shard of the chunks and runs the full
fused refactor chain — decompose -> quantize -> bitplane encode -> lossless
-> serialize — through ``ChunkedRefactorPipeline(mesh=...)``, exactly the
path ``store.DatasetWriter`` drives (not just the raw bitplane kernel).
Per-device workload is fixed (``CHUNKS_PER_DEV`` chunks of ``CHUNK_ELEMS``),
so ideal weak scaling keeps wall time flat as devices grow.

Host devices timeshare the container's few physical cores, so two numbers
are reported per device count n:

  ``weak_efficiency``     = t_1dev / t_n over the async-pipelined path —
                            the paper's weak-scaling metric (ideal 1.0,
                            only reachable while n <= cores; the paper
                            reports 89-95% on real GPUs; on a 1-core host
                            the ideal collapses to 1/n and is NOT gated);
  ``serialized_speedup``  = serial_wall / async_wall at the SAME device
                            count: the measured win of the async per-device
                            queues (batched drains: one scalar gather +
                            one stacked codec pass per window of
                            ``dispatch_ahead * n`` chunks) over the
                            round-barrier serial mode that finishes every
                            chunk with its own 3 host syncs;
  ``sync_amortization``   = serial syncs-per-chunk / async syncs-per-chunk,
                            from the codec engine's counters: the
                            scheduling layer's batching factor, exactly
                            ``dispatch_ahead * n`` when every drain window
                            fills (8.0 at 4 devices).  Counter-based, so it
                            is deterministic and host-core-independent —
                            this is the >= 2x async-vs-serialized gate.

On a 1-core host the WALL ratio is capped well below the sync
amortization: both modes run identical device compute (dispatch, codec
kernels, Algorithm-2 selection, serialization) on the same core, and only
the per-finish host overhead (~0.2 ms/chunk of the ~1.5 ms/chunk total)
is amortizable, bounding serial/async near 1.4 regardless of window
depth.  On real multi-GPU hosts the wall ratio approaches the
amortization factor because the batched drain also uncovers cross-device
compute overlap the round-barrier forfeits.

Writes ``out/benchmarks/weak_scaling.json`` with per-device-count
throughput and efficiency (the CI bench artifact).  ``run(devices=N)``
narrows the matrix to {1, N} (the ``benchmarks.run --devices`` knob).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path
from typing import List, Optional

from benchmarks.common import row, write_json

# 8 Ki-elem chunks, 16 per device: enough chunks that every drain window
# fills several times over, and a per-chunk host-overhead fraction large
# enough that the serial-vs-async wall ratio is stable run to run (at
# 64 Ki-elem chunks the shared compute drowns the ~0.2 ms/chunk amortizable
# overhead and the ratio wanders across 1.0)
CHUNK_ELEMS = 1 << 13
CHUNKS_PER_DEV = 16
DISPATCH_AHEAD = 2

_SCRIPT = rf"""
import json, time
import numpy as np, jax
from repro.core import lossless_batch as lb
from repro.core import pipeline as pl
from repro.core import sharded as shd

from repro.data.fields import gaussian_field

n_dev = len(jax.devices())
chunk_elems = {CHUNK_ELEMS}
n = n_dev * {CHUNKS_PER_DEV} * chunk_elems
# smooth spectral field, like the other write benchmarks: compressible but
# not trivial (pure iid noise stores at ratio < 1 at this chunk size, which
# would gate compression on data no refactorer targets)
x = gaussian_field((n,), slope=-2.0, seed=0)
mesh = shd.make_chunk_mesh(n_dev)

def write(pipelined):
    # dispatch_ahead pinned + tune cache off: the artifact must measure THIS
    # window depth, not whatever a stale cache on the CI host tuned last week
    pipe = pl.ChunkedRefactorPipeline(chunk_elems=chunk_elems, levels=2,
                                      mesh=mesh, pipelined=pipelined,
                                      dispatch_ahead={DISPATCH_AHEAD},
                                      use_tune_cache=False)
    pipe.refactor(x, name="v")
    return pipe

def timed(pipelined):
    write(pipelined)  # warm the jit caches (compile amortized in practice)
    lb.STATS.reset()
    ts = []
    for _ in range(3):
        t0 = time.perf_counter()
        pipe = write(pipelined)
        ts.append(time.perf_counter() - t0)
    syncs = lb.STATS.snapshot()["host_syncs"] / 3  # 3 identical timed runs
    return sorted(ts)[1], syncs / pipe.stats.chunks, pipe

serial_dt, serial_spc, _ = timed(pipelined=False)
dt, async_spc, pipe = timed(pipelined=True)

print("RESULT " + json.dumps({{
    "devices": n_dev, "wall_s": dt, "serial_wall_s": serial_dt,
    "chunks": pipe.stats.chunks,
    "serial_syncs_per_chunk": serial_spc,
    "async_syncs_per_chunk": async_spc,
    "sync_amortization": serial_spc / async_spc,
    "bytes_in": pipe.stats.bytes_in, "bytes_out": pipe.stats.bytes_out,
    "compression_ratio": pipe.stats.bytes_in / max(pipe.stats.bytes_out, 1),
    "gbps": pipe.stats.bytes_in / dt / 1e9}}))
"""


def _one(n_dev: int, repo: Path) -> Optional[dict]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = str(repo / "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    out = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")]
    if r.returncode != 0 or not out:
        sys.stderr.write(r.stderr[-2000:])
        return None
    return json.loads(out[0][len("RESULT "):])


def run(devices: Optional[int] = None) -> List[str]:
    counts = [1, 2, 4, 8] if devices is None else sorted({1, int(devices)})
    repo = Path(__file__).resolve().parents[1]
    lines, results, base = [], [], None
    for n in counts:
        res = _one(n, repo)
        if res is None:
            lines.append(row(f"weak_scaling_{n}dev", 0.0, "FAILED"))
            continue
        if n == 1:
            base = res["wall_s"]
        # serialized_speedup is same-count serial/async: always computable.
        # weak_efficiency needs the 1-device async baseline; if that run
        # FAILED, later rows report no_baseline instead of a bogus ratio.
        res["serialized_speedup"] = res["serial_wall_s"] / res["wall_s"]
        if base is None:
            res["weak_efficiency"] = None
            derived = (f"{res['gbps']:.4f}GBps;"
                       f"serialized_speedup={res['serialized_speedup']:.2f};"
                       f"sync_amortization={res['sync_amortization']:.1f};"
                       "no_baseline")
        else:
            res["weak_efficiency"] = base / res["wall_s"]
            derived = (f"{res['gbps']:.4f}GBps;"
                       f"weak_efficiency={res['weak_efficiency']:.2f};"
                       f"serialized_speedup={res['serialized_speedup']:.2f};"
                       f"sync_amortization={res['sync_amortization']:.1f};"
                       f"compression={res['compression_ratio']:.3f}")
        results.append(res)
        lines.append(row(f"weak_scaling_{n}dev", res["wall_s"], derived))
    write_json("weak_scaling", {
        "bench": "weak_scaling", "path": "ChunkedRefactorPipeline(mesh=...)",
        "chunk_elems": CHUNK_ELEMS, "chunks_per_device": CHUNKS_PER_DEV,
        "dispatch_ahead": DISPATCH_AHEAD,
        "host_cores": os.cpu_count(),
        "results": results})
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
