"""Generate EXPERIMENTS.md from the dry-run/roofline artifacts + the §Perf
iteration measurements.  Rerun after refreshing out/dryrun to update tables.

    PYTHONPATH=src python benchmarks/make_experiments.py
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks import roofline_dryrun as R

REPO = Path(__file__).resolve().parents[1]
PEAK, HBM, LINK = R.PEAK_FLOPS, R.HBM_BW, R.LINK_BW


def _cell(path: str):
    p = REPO / "out" / path
    if not p.exists():
        return None
    r = json.loads(p.read_text())
    if r.get("status") != "ok":
        return None
    return {
        "c": r["flops_per_device"] / PEAK,
        "m": r["hbm_bytes_per_device"] / HBM,
        "x": r["collectives"]["wire_bytes_per_device"] / LINK,
        "temp": r["memory"]["temp_bytes"] / 1e9,
        "args": r["memory"]["argument_bytes"] / 1e9,
        "compile_s": r.get("compile_s", 0),
    }


def perf_row(label, base, new, note=""):
    if base is None or new is None:
        return f"| {label} | (pending) | | | | {note} |\n"
    b = max(base["c"], base["m"], base["x"])
    n = max(new["c"], new["m"], new["x"])
    return (f"| {label} | {b:.2f} s | {n:.2f} s | {b / max(n, 1e-9):.1f}x | "
            f"c {base['c']:.2f}->{new['c']:.2f} / m {base['m']:.2f}->"
            f"{new['m']:.2f} / x {base['x']:.2f}->{new['x']:.2f} | {note} |\n")


def dryrun_summary():
    ok = fail = skip = 0
    worst_mem = 0.0
    for p in (REPO / "out" / "dryrun").glob("*.json"):
        if "__micro" in p.name or "moe_shard_map" in p.name or \
           "tp_only" in p.name or "kv_int8" in p.name:
            continue
        r = json.loads(p.read_text())
        ok += r["status"] == "ok"
        fail += r["status"] == "fail"
        skip += r["status"] == "skip"
    return ok, fail, skip


def main():
    rows_single = R.load_cells("single")
    rows_multi = R.load_cells("multi")
    ok, fail, skip = dryrun_summary()

    # ---- §Perf cells -------------------------------------------------------
    a0 = _cell("dryrun_baseline/deepseek-v3-671b__train_4k__single.json")
    a1 = _cell("dryrun/deepseek-v3-671b__train_4k__single__moe_shard_map.json")
    a2 = _cell("dryrun/deepseek-v3-671b__train_4k__single__micro8_moe_shard_map.json")
    a3 = _cell("dryrun/deepseek-v3-671b__train_4k__single__micro4_moe_shard_map.json")
    b0 = _cell("dryrun_baseline/rwkv6-3b__train_4k__single.json")
    b1 = _cell("dryrun/rwkv6-3b__train_4k__single.json")
    c0 = _cell("dryrun_baseline/deepseek-67b__decode_32k__single.json")
    c1 = _cell("dryrun/deepseek-67b__decode_32k__single__tp_only_params.json")
    c2 = _cell("dryrun/deepseek-67b__decode_32k__single__kv_int8_tp_only_params.json")
    v2_0 = _cell("dryrun_baseline/deepseek-v2-236b__train_4k__single.json")
    v2_1 = _cell("dryrun/deepseek-v2-236b__train_4k__single__moe_shard_map.json")
    j0 = _cell("dryrun_baseline/jamba-v0.1-52b__train_4k__single.json")
    j1 = _cell("dryrun/jamba-v0.1-52b__train_4k__single__moe_shard_map.json")
    p0 = _cell("dryrun_baseline/deepseek-v3-671b__prefill_32k__single.json")
    p1 = _cell("dryrun/deepseek-v3-671b__prefill_32k__single__moe_shard_map.json")

    out = []
    w = out.append
    w("# EXPERIMENTS — HP-MDR on TPU\n\n")
    w("Hardware model: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM, "
      "~50 GB/s/link ICI.  All numbers are derived from compiled dry-run "
      "artifacts (no TPU in this container); see DESIGN.md for the "
      "methodology and `repro/launch/hlo_analysis.py` for the loop-aware "
      "HLO cost model (XLA's cost_analysis counts while-loop bodies once; "
      "we multiply by `known_trip_count` and walk fusions).\n\n")

    # ---------------------------------------------------------- dry-run ----
    w("## §Dry-run\n\n")
    w(f"Every supported (arch x shape) cell lowers AND compiles on both "
      f"production meshes — **{ok} ok / {fail} failed / {skip} skipped** "
      f"records (skips per DESIGN.md §7: encoder-only decode, quadratic "
      f"long_500k).\n\n")
    w("* single-pod: `jax.make_mesh((16,16), ('data','model'))` — 256 chips\n")
    w("* multi-pod: `jax.make_mesh((2,16,16), ('pod','data','model'))` — "
      "512 chips; the pod axis extends data parallelism (gradient "
      "all-reduce crosses pods once per step)\n\n")
    w("Per-cell records (memory_analysis bytes, loop-aware FLOPs/HBM/"
      "collective-wire bytes, collective schedule by kind, policy) live in "
      "`out/dryrun/*.json` with the optimized HLO in `*.hlo.gz`.  "
      "Reproduce: `PYTHONPATH=src python -m repro.launch.dryrun`.\n\n")
    w("Memory fits (examples, per device of 16 GB):\n\n")
    for name, path in [
        ("deepseek-v3-671b train_4k (opt)", "dryrun/deepseek-v3-671b__train_4k__single__moe_shard_map.json"),
        ("deepseek-67b decode_32k (opt)", "dryrun/deepseek-67b__decode_32k__single__kv_int8_tp_only_params.json"),
        ("command-r-plus-104b train_4k", "dryrun/command-r-plus-104b__train_4k__single.json"),
    ]:
        c = _cell(path)
        if c:
            w(f"* {name}: arguments {c['args']:.1f} GB, XLA temp "
              f"{c['temp']:.1f} GB (CPU-backend fp32-inflated; bf16-dominant "
              f"buffers halve on TPU)\n")
    w("\n")

    # --------------------------------------------------------- roofline ----
    w("## §Roofline (single-pod, 256 chips — baseline table, all cells)\n\n")
    w("compute = HLO_FLOPs/dev / 197e12; memory = HBM-traffic/dev / 819e9; "
      "collective = wire-bytes/dev / 50e9.  `MODEL/HLO` = MODEL_FLOPS / "
      "HLO_FLOPs (remat + attention + dispatch waste).  `roofline frac` = "
      "min-achievable step time (max of MODEL_FLOPS/peak, MODEL_BYTES/bw) "
      "over the dominant-term estimate.\n\n")
    w(R.fmt_table(rows_single))
    w("\nMulti-pod (512 chips) highlights — the pod axis halves per-device "
      "batch; collective terms stay within 2x of single-pod (DCN hop = one "
      "gradient all-reduce):\n\n")
    w(R.fmt_table([r for r in rows_multi if r["shape"] == "train_4k"]))
    w("\nPer-cell 'what would move the dominant term':\n\n")
    for r in rows_single:
        w(f"* {r['arch']} x {r['shape']}: {r['dominant']}-bound -> "
          f"{R.IMPROVEMENT_NOTES[r['dominant']]}\n")
    w("\n")

    # ------------------------------------------------------------- perf ----
    w("## §Perf — hillclimb log (3 cells: most collective-bound / worst "
      "fraction / paper-technique-representative)\n\n")
    w("| cell + change | dominant before | after | gain | terms (c/m/x, s) | "
      "verdict |\n|---|---|---|---|---|---|\n")
    w(perf_row("A1 deepseek-v3 train_4k: MoE dispatch GSPMD->shard_map EP",
               a0, a1, "CONFIRMED (hyp: partitioner materializes the "
               "(E,C,D) buffer via all-reduce and replicates expert compute "
               "over DP; manual EP removes both)"))
    w(perf_row("A2 + n_micro 16->8 (halve FSDP re-gathers)", a1, a2,
               "see log below"))
    w(perf_row("A3 + n_micro 16->4", a1, a3, "see log below"))
    w(perf_row("B1 rwkv6 train_4k: chunked-remat WKV scan", b0, b1,
               "peak temp 171->75 GB (the actual goal); traffic terms flat "
               "-> PARTIALLY CONFIRMED"))
    w(perf_row("C1 deepseek-67b decode_32k: serving TP-only params "
               "(drop FSDP gathers)", c0, c1,
               "CONFIRMED (collective 62x down; weights now resident)"))
    w(perf_row("C2 + int8 exponent-aligned KV cache (HP-MDR on the cache)",
               c1, c2, "CONFIRMED (cache read bytes halved)"))
    w("\nSame change, other MoE cells (the fix generalizes):\n\n")
    w("| cell | dominant before | after | gain | terms | |\n|---|---|---|---|---|---|\n")
    w(perf_row("deepseek-v2 train_4k: shard_map EP", v2_0, v2_1))
    w(perf_row("jamba-v0.1 train_4k: shard_map EP", j0, j1))
    w(perf_row("deepseek-v3 prefill_32k: shard_map EP", p0, p1))

    w("\n### Iteration narratives (hypothesis -> change -> measure -> verdict)"
      "\n\n")
    w(open(REPO / "benchmarks" / "perf_log.md").read()
      if (REPO / "benchmarks" / "perf_log.md").exists() else "")

    # ------------------------------------------------------- validation ----
    w("\n## §Validation vs the paper's claims\n\n")
    bench = REPO / "bench_output.txt"
    rows = {}
    if bench.exists():
        for line in bench.read_text().splitlines():
            parts = line.split(",", 2)
            if len(parts) == 3:
                rows[parts[0]] = (parts[1], parts[2])

    def get(name, default="(run benchmarks)"):
        return rows.get(name, (None, default))[1]

    n_guar = sum(1 for k, v in rows.items()
                 if k.startswith("qoi_") and "guarantee=OK" in v[1])
    n_qoi = sum(1 for k in rows if k.startswith("qoi_"))
    w("Benchmark CSV: `bench_output.txt` (regenerate with "
      "`PYTHONPATH=src python -m benchmarks.run`).  Behavioral claims "
      "checked — absolute GB/s are NOT comparable (CPU container vs "
      "H100/MI250X); relative/structural behavior is:\n\n")
    w("| paper claim | our measurement | file |\n|---|---|---|\n")
    w("| register block fastest on GPU (Fig 7) | all 3 designs bit-exact "
      "portable; on THIS CPU the ordering inverts (lane-strided interleave "
      "is cache-hostile on CPU) — consistent with the paper's thesis that "
      "execution design must match the architecture while the FORMAT stays "
      "portable; the TPU version is the Pallas register_block kernel | "
      "`bitplane_designs` |\n")
    w(f"| hybrid ~ Huffman retrieval size at higher throughput (Fig 8: +8% "
      f"at rc=1) | hybrid_rc1 {get('lossless_retrieval_overhead_hybrid_rc1')}"
      f", rc2 {get('lossless_retrieval_overhead_hybrid_rc2')}, RLE-always "
      f"{get('lossless_retrieval_overhead_rle')} (paper: +270%) | "
      f"`lossless_strategies` |\n")
    w(f"| pipeline overlap 1.43-1.83x (Fig 9) | "
      f"{get('pipeline_speedup')} (host-thread overlap on 1 core) | "
      f"`pipeline_overlap` |\n")
    w(f"| 89-95% weak scaling (Fig 10) | 8-dev "
      f"{get('weak_scaling_8dev')} | `weak_scaling` |\n")
    w(f"| HP-MDR competitive retrieval size, higher throughput (Fig 11) | "
      f"retrieval bytes at 1e-6: hpmdr "
      f"{get('e2e_retrieve_hpmdr_1e-06')} vs multi-component "
      f"{get('e2e_retrieve_multi_comp_1e-06')} | `end_to_end` |\n")
    w(f"| MA best bitrate / CP fewest iters / MAPE tradeoff (Tab 2/3) | "
      f"e.g. NYX tau=1e-3: CP {get('qoi_nyx_cp_1e-03')}; MA "
      f"{get('qoi_nyx_ma_1e-03')}; MAPE "
      f"{get('qoi_nyx_mape_c10_1e-03')} | `qoi_benchmarks` |\n")
    w(f"| actual <= estimated <= requested QoI error (Fig 13) | "
      f"guarantee held in {n_guar}/{n_qoi} benchmark cells (also a pytest "
      f"property) | `qoi_benchmarks` |\n")
    w(f"| (ours) compressed gradient collective | 4-plane wire "
      f"{get('gradcomp_wire_comp4')} | `grad_compress_bench` |\n")

    (REPO / "EXPERIMENTS.md").write_text("".join(out))
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
