"""Write-path benchmark: fused one-dispatch encode vs the per-piece path.

Measures, through the chunked refactor pipeline (pipelined mode, the paper's
Fig-4 DAG), the two costs the fused engine removes:

  * jitted-dispatch count per chunk at the tracked dispatch sites
    (``align_encode`` + ``encode_bitplanes`` + the fused program launch):
    the fused path launches ONE program per chunk, the per-piece path ~3 per
    piece — and that undercounts the per-piece path, whose eager multilevel
    decompose adds several more dispatches per level;
  * end-to-end write throughput (fused must be >= per-piece — the CI
    acceptance check).

Emits CSV rows and writes ``out/benchmarks/refactor_benchmarks.json`` (same
artifact convention as ``qoi_benchmarks`` / ``store_serving``).
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import row, timeit, write_json
from repro.core import align as al
from repro.core import lossless_batch as lb
from repro.core import pipeline as pl
from repro.core import refactor_fused as rff
from repro.kernels import ops as kops
from repro.data.fields import gaussian_field
from repro.obs import trace as obs_trace

CHUNK_ELEMS = 1 << 16
N_CHUNKS = 6
LEVELS = 3


class _DispatchCounter:
    """Counts Python-level invocations of the per-piece jitted dispatch
    sites; each call is one XLA dispatch on a warm cache."""

    def __init__(self):
        self.count = 0
        self._saved = []

    def __enter__(self):
        for mod, name in [(kops, "encode_bitplanes"),
                          (kops, "encode_bitplanes_batch"),
                          (al, "align_encode")]:
            orig = getattr(mod, name)
            self._saved.append((mod, name, orig))

            def wrapper(*a, _orig=orig, **kw):
                self.count += 1
                return _orig(*a, **kw)

            setattr(mod, name, wrapper)
        return self

    def __exit__(self, *exc):
        for mod, name, orig in self._saved:
            setattr(mod, name, orig)


def _run_mode(x: np.ndarray, fused: bool) -> Dict:
    def make_pipe():
        return pl.ChunkedRefactorPipeline(chunk_elems=CHUNK_ELEMS,
                                          pipelined=True, levels=LEVELS,
                                          fused=fused)

    make_pipe().refactor(x, "warmup")  # compile/plan caches
    lb.STATS.reset()
    rff.STATS.reset()
    with _DispatchCounter() as dc:
        pipe = make_pipe()
        pipe.refactor(x, "count")
    chunks = pipe.stats.chunks
    snap = lb.STATS.snapshot()
    fused_snap = rff.STATS.snapshot()
    dispatches = dc.count + (fused_snap["dispatches"] if fused else 0)

    secs = timeit(lambda: make_pipe().refactor(x, "bench"), warmup=1, iters=3)
    return {
        "fused": fused,
        "seconds": secs,
        "throughput_gbps": x.nbytes / secs / 1e9,
        "chunks": chunks,
        "dispatches_per_chunk": dispatches / chunks,
        "host_syncs_per_chunk": snap["host_syncs"] / chunks,
        "codec_host_syncs": snap["host_syncs"],
        "compression_ratio": pipe.stats.bytes_in / max(pipe.stats.bytes_out,
                                                       1),
    }


def _run_tuned(x: np.ndarray) -> Dict:
    """Tuner-selected config through the same pipelined fused write.

    ``tune`` consults the on-disk cache first (fresh CI runs search; local
    re-runs replay).  The winner is the best MEASURED probe with the default
    always probed, so ``probe_speedup >= 1.0`` whenever a search ran; on a
    cache hit the probes are re-measured here so the artifact always carries
    them."""
    from repro import tune as tn
    from repro.tune.search import _measure_write, _probe_chunk

    res = tn.tune((CHUNK_ELEMS,), levels=LEVELS, probes=4)
    cfg = res.config
    if res.probes:
        default_probe = res.probes[0][1]
        winner_probe = min(s for _, s in res.probes)
    else:
        xp = _probe_chunk((CHUNK_ELEMS,), "float32")
        default_probe = _measure_write(xp, tn.DEFAULT_CONFIG, LEVELS)
        winner_probe = _measure_write(xp, cfg, LEVELS)

    def make_pipe():
        return pl.ChunkedRefactorPipeline(chunk_elems=CHUNK_ELEMS,
                                          pipelined=True, levels=LEVELS,
                                          fused=True, config=cfg)

    make_pipe().refactor(x, "warmup")
    secs = timeit(lambda: make_pipe().refactor(x, "bench"), warmup=1, iters=3)
    pipe = make_pipe()
    pipe.refactor(x, "stats")
    return {
        "config": cfg.to_json(),
        "cache_hit": res.cache_hit,
        "tune_s": res.tune_s,
        "seconds": secs,
        "throughput_gbps": x.nbytes / secs / 1e9,
        "default_probe_s": default_probe,
        "winner_probe_s": winner_probe,
        "probe_speedup": default_probe / max(winner_probe, 1e-12),
        "compression_ratio": pipe.stats.bytes_in / max(pipe.stats.bytes_out,
                                                       1),
    }


def _tracing_overhead(x: np.ndarray) -> Dict:
    """Wall-time cost of the obs layer on the fused write path.

    ``disabled`` times the default state (no tracer installed: every
    ``span()`` is one ContextVar read returning the shared null manager —
    the <2%% contract measured against ``enabled``); ``enabled`` times the
    same write under a full tracer."""
    def write():
        pl.ChunkedRefactorPipeline(chunk_elems=CHUNK_ELEMS, pipelined=True,
                                   levels=LEVELS,
                                   fused=True).refactor(x, "ovh")

    def write_off():
        with obs_trace.no_tracing():  # run.py traces the module: force off
            write()

    def write_traced():
        with obs_trace.tracing():
            write()

    write_off()  # warm caches
    write_traced()
    # the tracer's true cost (~0% of a write) sits well below the 1-core
    # host's run-to-run spread (±5%), so timing each mode in its own block
    # measures drift, not overhead.  Interleave off/on pairs so both modes
    # see the same cache/frequency state, and take per-mode minima — the
    # minimum is the least noise-contaminated observation of each.
    offs, ons = [], []
    for _ in range(7):
        t0 = time.perf_counter()
        write_off()
        offs.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        write_traced()
        ons.append(time.perf_counter() - t0)
    t_off, t_on = min(offs), min(ons)
    return {
        "disabled_s": t_off,
        "enabled_s": t_on,
        "enabled_overhead_pct": (t_on - t_off) / t_off * 100.0,
    }


def run() -> list:
    x = gaussian_field((N_CHUNKS * CHUNK_ELEMS,), slope=-2.0, seed=12)
    per_piece = _run_mode(x, fused=False)
    fused = _run_mode(x, fused=True)
    tuned = _run_tuned(x)
    overhead = _tracing_overhead(x)
    result = {
        "chunk_elems": CHUNK_ELEMS,
        "n_chunks": N_CHUNKS,
        "levels": LEVELS,
        "bytes_in": int(x.nbytes),
        "per_piece": per_piece,
        "fused": fused,
        "speedup": per_piece["seconds"] / fused["seconds"],
        # CI acceptance: strictly fewer dispatches AND >= throughput
        "dispatch_reduction": (per_piece["dispatches_per_chunk"]
                               / max(fused["dispatches_per_chunk"], 1e-9)),
        "fused_dispatches_below_per_piece": (
            fused["dispatches_per_chunk"] < per_piece["dispatches_per_chunk"]),
        "fused_throughput_ge_per_piece": (
            fused["throughput_gbps"] >= per_piece["throughput_gbps"]),
        # autotuned write: winner of repro.tune's measured-probe search on
        # this (shape, backend); probe_speedup >= 1.0 by construction when
        # the search ran here (default is always probed)
        "tuned": tuned,
        "tuned_speedup_vs_fused": fused["seconds"] / tuned["seconds"],
        "tracing": overhead,
    }
    write_json("refactor_benchmarks", result)
    lines = []
    for mode in (per_piece, fused):
        tag = "fused" if mode["fused"] else "per_piece"
        lines.append(row(
            f"refactor_write_{tag}", mode["seconds"],
            f"tput={mode['throughput_gbps']:.4f}GBps;"
            f"dispatches_per_chunk={mode['dispatches_per_chunk']:.1f};"
            f"syncs_per_chunk={mode['host_syncs_per_chunk']:.1f};"
            f"compression={mode['compression_ratio']:.3f}"))
    lines.append(row(
        "refactor_write_fused_vs_per_piece", fused["seconds"],
        f"speedup={result['speedup']:.2f}x;"
        f"dispatch_reduction={result['dispatch_reduction']:.1f}x;"
        f"dispatches_ok={result['fused_dispatches_below_per_piece']};"
        f"throughput_ok={result['fused_throughput_ge_per_piece']}"))
    lines.append(row(
        "refactor_write_tuned", tuned["seconds"],
        f"tput={tuned['throughput_gbps']:.4f}GBps;"
        f"probe_speedup={tuned['probe_speedup']:.3f};"
        f"design={tuned['config']['design']};"
        f"group={tuned['config']['group_size']};"
        f"cache_hit={tuned['cache_hit']}"))
    lines.append(row(
        "refactor_write_tracing_overhead", overhead["enabled_s"],
        f"enabled_pct={overhead['enabled_overhead_pct']:.2f}"))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
