"""Legacy roofline over the LLM dry-run artifacts (EXPERIMENTS.md tables).

Moved out of ``benchmarks/roofline.py`` when that module became the HP-MDR
fused-write roofline (peaks now live in ``repro.tune.cost``).  This module
keeps the (arch x shape x mesh) cell analysis that
``benchmarks/make_experiments.py`` renders from ``out/dryrun/*.json``.

Per cell:

  compute term    = flops_per_device / PEAK_FLOPS
  memory term     = hbm_bytes_per_device / HBM_BW
  collective term = wire_bytes_per_device / LINK_BW

MODEL_FLOPS (per device):
  train:   6 * N_active * tokens / chips      (fwd+bwd weight flops)
  prefill: 2 * N_active * tokens / chips
  decode:  2 * N_active * batch  / chips  + cache-read attention flops

The ratio MODEL_FLOPS / HLO_FLOPs exposes remat recompute and masked-block
attention waste.  The dominant term is the roofline bottleneck; the perf
loop (EXPERIMENTS.md §Perf) iterates on whichever dominates.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

from repro.tune.cost import HBM_BW, LINK_BW, PEAK_FLOPS

OUT_DIR = Path(__file__).resolve().parents[1] / "out" / "dryrun"

ARCHS = ["rwkv6-3b", "deepseek-67b", "h2o-danube-3-4b", "command-r-plus-104b",
         "qwen2-7b", "hubert-xlarge", "jamba-v0.1-52b", "deepseek-v2-236b",
         "deepseek-v3-671b", "llama-3.2-vision-90b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    from repro.configs.base import SHAPES as SH, get_config
    from repro.models.model import count_params
    cfg = get_config(arch)
    shape = SH[shape_name]
    n_act = count_params(cfg, active_only=True)
    if shape.kind == "train":
        return 6.0 * n_act * shape.global_batch * shape.seq_len / chips
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len / chips
    # decode: weight flops for B tokens + attention cache dot-products
    flops = 2.0 * n_act * shape.global_batch
    if not (cfg.ssm and cfg.ssm.kind == "rwkv6"):
        L = min(cfg.attn_window or shape.seq_len, shape.seq_len)
        if cfg.mla:
            dh_k = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
            dh_v = cfg.mla.kv_lora_rank
            n_attn_layers = cfg.n_layers
            flops += (2.0 * cfg.n_heads * (dh_k + dh_v) * L
                      * shape.global_batch * n_attn_layers)
        else:
            n_attn = cfg.n_layers
            if cfg.ssm and cfg.ssm.attn_period:
                n_attn = cfg.n_layers // cfg.ssm.attn_period
            flops += (2.0 * cfg.n_heads * 2 * cfg.head_dim * L
                      * shape.global_batch * n_attn)
    return flops / chips


def model_bytes_per_device(arch: str, shape_name: str, chips: int,
                           policy: Dict) -> float:
    """Minimum achievable HBM traffic per device per step (the memory-roofline
    numerator): every resident weight byte read once per (micro)batch pass,
    plus optimizer traffic for train, plus one cache read for decode."""
    from repro.configs.base import SHAPES as SH, get_config
    from repro.models.model import count_params
    cfg = get_config(arch)
    shape = SH[shape_name]
    n = count_params(cfg)
    pbytes = n * (2 if cfg.param_dtype == "bfloat16" else 4) / chips
    if shape.kind == "train":
        n_micro = max(policy.get("n_micro", 1), 1)
        opt_b = 2 if policy.get("opt_state_dtype") == "bfloat16" else 4
        # fwd + bwd weight reads per microbatch (+1 recompute with remat),
        # grad write/read + adam m,v read+write + param update
        return pbytes * (3 * n_micro + 2) + (n / chips) * opt_b * 4
    if shape.kind == "prefill":
        act = shape.global_batch * shape.seq_len * cfg.d_model * 2 / chips
        return pbytes + act * cfg.n_layers * 2
    # decode: weights once + one full cache read
    cache = 0.0
    if not (cfg.ssm and cfg.ssm.kind == "rwkv6"):
        L = policy.get("cache_len", shape.seq_len)
        n_attn = cfg.n_layers
        if cfg.ssm and cfg.ssm.attn_period:
            n_attn = cfg.n_layers // cfg.ssm.attn_period
        if cfg.mla:
            per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
        else:
            per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
        cache = shape.global_batch * L * per_tok * 2 * n_attn / chips
    state = 0.0
    if cfg.ssm:
        d = cfg.d_model
        if cfg.ssm.kind == "rwkv6":
            state = cfg.n_layers * shape.global_batch * (d // 64) * 64 * 64 * 4 / chips
        else:
            n_mamba = cfg.n_layers - (cfg.n_layers // max(cfg.ssm.attn_period, 1)
                                      if cfg.ssm.attn_period else 0)
            state = n_mamba * shape.global_batch * cfg.ssm.expand * d \
                * cfg.ssm.d_state * 4 / chips
    return pbytes + cache + state * 2


def load_cells(mesh: str = "single") -> List[Dict]:
    rows = []
    for a in ARCHS:
        for s in SHAPES:
            p = OUT_DIR / f"{a}__{s}__{mesh}.json"
            if not p.exists():
                continue
            r = json.loads(p.read_text())
            if r["status"] != "ok":
                continue
            chips = 512 if mesh == "multi" else 256
            t_c = r["flops_per_device"] / PEAK_FLOPS
            t_m = r["hbm_bytes_per_device"] / HBM_BW
            t_x = r["collectives"]["wire_bytes_per_device"] / LINK_BW
            dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
            mf = model_flops_per_device(a, s, chips)
            mb = model_bytes_per_device(a, s, chips, r.get("policy", {}))
            # minimum achievable step time on ANY resource vs estimated time
            # on the dominant resource
            t_min = max(mf / PEAK_FLOPS, mb / HBM_BW)
            rows.append({
                "arch": a, "shape": s, "mesh": mesh, "chips": chips,
                "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
                "dominant": dom[1], "bound_s": dom[0],
                "model_flops": mf, "model_bytes": mb,
                "useful_ratio": mf / max(r["flops_per_device"], 1.0),
                "roofline_fraction": min(t_min / max(dom[0], 1e-30), 1.0),
                "memory_gb": {k: v / 1e9 for k, v in r["memory"].items()},
                "policy": r.get("policy", {}),
            })
    return rows


IMPROVEMENT_NOTES = {
    "compute": "cut remat recompute (checkpoint dots-only) or raise per-chip "
               "batch to amortize fixed work",
    "memory": "decode/SSM cells are HBM-bound by cache/state reads: quantize "
              "the KV cache (HP-MDR bitplane truncation) or batch more "
              "queries per cache pass",
    "collective": "shrink per-layer all-gathers: two-level FSDP gather "
                  "(pod-local), bitplane-compressed gradient all-gather "
                  "(grad_compress), or overlap via latency hiding",
}


def fmt_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL_FLOPS/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.1%} |\n")
    return "".join(out)


if __name__ == "__main__":
    rows = load_cells("single")
    print(fmt_table(rows))
