# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: one module per paper table/figure (see DESIGN.md §9).

  Fig 6/7   bitplane_designs        Fig 8    lossless_strategies
  Fig 9     pipeline_overlap        Fig 10   weak_scaling
  Fig 11    end_to_end              Tab 2/3 + Fig 12/13/14  qoi_benchmarks
  (ours)    grad_compress_bench     (ours)   roofline (fused-write HLO
            roofline + measured probes, peaks from repro.tune.cost)
  (ours)    store_serving (cold/warm cache, sessions, bytes-vs-tol; also
            writes out/benchmarks/store_serving.json)
  (ours)    serving_load (open-loop Zipf load generator against the serving
            tier: hit-path speedup, p50/p99, cache-hit + coalesced ratios;
            writes out/benchmarks/serving_load.json)
  (ours)    autotune_smoke (repro.tune search + cache-hit replay + store
            plan round-trip; writes out/benchmarks/autotune_smoke.json)

Usage: PYTHONPATH=src python -m benchmarks.run [--only MODULE] [--devices N]

``--devices N`` forwards a device count to every benchmark whose ``run``
accepts a ``devices`` keyword (the mesh-sharded ones, e.g. weak_scaling),
so the bench matrix covers 1 vs N host devices; benchmarks without the
knob run unchanged.
"""
import argparse
import inspect
import sys
import traceback

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

MODULES = [
    "bitplane_designs",
    "lossless_strategies",
    "pipeline_overlap",
    "refactor_benchmarks",
    "weak_scaling",
    "end_to_end",
    "qoi_benchmarks",
    "grad_compress_bench",
    "store_serving",
    "serving_load",
    "autotune_smoke",
    "roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--devices", type=int, default=None,
                    help="device count forwarded to sharding-aware benchmarks")
    args = ap.parse_args()
    mods = [args.only] if args.only else MODULES
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            kw = {}
            if (args.devices is not None
                    and "devices" in inspect.signature(mod.run).parameters):
                kw["devices"] = args.devices
            # per-module tracing + metrics scope: each module's write_json
            # artifact carries ITS spans/counters only (common.write_json
            # attaches the snapshot and the Chrome trace file)
            with obs_metrics.scope(), obs_trace.tracing():
                for line in mod.run(**kw):
                    print(line)
            sys.stdout.flush()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR")
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
