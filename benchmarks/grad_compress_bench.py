"""Beyond-paper: progressive gradient compression — collective wire bytes of
the compressed allreduce vs plain psum, from lowered HLO on 8 host devices
(subprocess), plus encode throughput on this host."""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import timeit, row
from repro.distributed.grad_compress import ef_quantize

_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.grad_compress import make_compressed_allreduce
from repro.launch.hlo_analysis import HloAnalysis
mesh = jax.make_mesh((8,), ("data",))
n = 1 << 22
xs = jax.ShapeDtypeStruct((8, n), jnp.float32)
sh = NamedSharding(mesh, P("data", None))
with mesh:
    cp = jax.jit(lambda x: jnp.mean(x, axis=0), in_shardings=(sh,),
                 out_shardings=NamedSharding(mesh, P())).lower(xs).compile()
    for planes in [4, 8, 12]:
        cc = jax.jit(make_compressed_allreduce(mesh, "data", planes=planes),
                     in_shardings=(sh,)).lower(xs).compile()
        wc = HloAnalysis(cc.as_text()).summary()["collective_wire_bytes_per_device"]
        print(f"RESULT comp{planes} {wc:.0f}")
    wp = HloAnalysis(cp.as_text()).summary()["collective_wire_bytes_per_device"]
    print(f"RESULT plain {wp:.0f}")
"""


def run() -> list:
    lines = []
    repo = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(repo / "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    vals = {}
    for l in r.stdout.splitlines():
        if l.startswith("RESULT"):
            _, k, v = l.split()
            vals[k] = float(v)
    if "plain" in vals:
        for k, v in vals.items():
            if k == "plain":
                lines.append(row("gradcomp_wire_plain_psum", 0.0, f"{v:.0f}B"))
            else:
                lines.append(row(f"gradcomp_wire_{k}", 0.0,
                                 f"{v:.0f}B;{v / vals['plain']:.2%}_of_plain"))
    else:
        lines.append(row("gradcomp_wire", 0.0, "FAILED:" + r.stderr[-200:]))
    # encode throughput (error-feedback quantize path)
    g = jnp.asarray(np.random.default_rng(0).normal(size=1 << 22).astype(np.float32))
    res = jnp.zeros_like(g)
    f = jax.jit(lambda a, b: ef_quantize(a, b, 8))
    jax.block_until_ready(f(g, res))
    t = timeit(lambda: jax.block_until_ready(f(g, res)))
    lines.append(row("gradcomp_ef_quantize_4M", t,
                     f"{g.nbytes / 1e9 / t:.3f}GBps"))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
