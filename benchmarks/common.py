"""Shared benchmark utilities: timing, CSV rows, JSON artifacts.

``write_json`` is the single exit door for benchmark results: when an
``obs`` tracing context and/or metrics scope is active (``run.py`` installs
both per benchmark module), the artifact automatically gains an ``"obs"``
section — the metrics snapshot, the tracer's span/event summary with
host-sync attribution — and the full Chrome trace is written next to it as
``<name>.trace.json`` (load it in ``chrome://tracing`` or
https://ui.perfetto.dev).  CI uploads both and gates budgets on the JSON
via ``benchmarks/check_regressions.py``.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable, Dict, List

from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

REPO = Path(__file__).resolve().parents[1]


def timeit(fn: Callable, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[(len(ts) - 1) // 2]  # lower median: 2 iters -> the warm one


def row(name: str, seconds: float, derived: str) -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


def write_json(name: str, obj: Dict) -> Path:
    """Write a result dict to out/benchmarks/<name>.json (CI artifact).

    Under an active obs tracing context / metrics scope, attaches the
    ``"obs"`` section (metrics snapshot + span/event summary with host-sync
    attribution) and writes the Chrome trace to ``<name>.trace.json``."""
    out = REPO / "out" / "benchmarks"
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"{name}.json"
    obs: Dict = {}
    snap = obs_metrics.snapshot()
    if any(snap.values()):
        obs["metrics"] = snap
    tracer = obs_trace.current_tracer()
    if tracer is not None and (tracer.spans() or tracer.orphan_events()):
        obs["trace_summary"] = tracer.summary()
        trace_path = out / f"{name}.trace.json"
        obs_export.write_chrome_trace(trace_path, tracer)
        obs["trace_file"] = trace_path.name
    if obs:
        obj = {**obj, "obs": obs}
    path.write_text(json.dumps(obj, indent=1))
    return path


def codec_batches(codec: Dict[str, int]) -> Dict[str, int]:
    """Collapse a ``lossless_batch.BatchStats`` snapshot into the encode /
    decode batch-launch counts the benchmark reports (single definition so
    a counter rename cannot drift between benchmarks)."""
    return {
        "enc_batches": (codec["hist_batches"] + codec["huffman_pack_batches"]
                        + codec["rle_scan_batches"]),
        "dec_batches": (codec["huffman_unpack_batches"]
                        + codec["rle_expand_batches"]),
        "host_syncs": codec["host_syncs"],
    }
