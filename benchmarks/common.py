"""Shared benchmark utilities: timing, CSV rows, JSON artifacts."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable, Dict, List

REPO = Path(__file__).resolve().parents[1]


def timeit(fn: Callable, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[(len(ts) - 1) // 2]  # lower median: 2 iters -> the warm one


def row(name: str, seconds: float, derived: str) -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


def write_json(name: str, obj: Dict) -> Path:
    """Write a result dict to out/benchmarks/<name>.json (CI artifact)."""
    out = REPO / "out" / "benchmarks"
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"{name}.json"
    path.write_text(json.dumps(obj, indent=1))
    return path


def codec_batches(codec: Dict[str, int]) -> Dict[str, int]:
    """Collapse a ``lossless_batch.BatchStats`` snapshot into the encode /
    decode batch-launch counts the benchmark reports (single definition so
    a counter rename cannot drift between benchmarks)."""
    return {
        "enc_batches": (codec["hist_batches"] + codec["huffman_pack_batches"]
                        + codec["rle_scan_batches"]),
        "dec_batches": (codec["huffman_unpack_batches"]
                        + codec["rle_expand_batches"]),
        "host_syncs": codec["host_syncs"],
    }
