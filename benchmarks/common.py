"""Shared benchmark utilities: timing, CSV rows."""
from __future__ import annotations

import time
from typing import Callable, List


def timeit(fn: Callable, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[(len(ts) - 1) // 2]  # lower median: 2 iters -> the warm one


def row(name: str, seconds: float, derived: str) -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
