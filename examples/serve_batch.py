"""Batched serving: prefill a batch of prompts, decode new tokens with the
KV cache (GQA or MLA absorbed cache, per --arch smoke config).

    PYTHONPATH=src python examples/serve_batch.py --arch qwen2-7b --new-tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import smoke_config, list_archs
from repro.models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new_tokens

    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    vis = None
    if cfg.cross_attn_period:
        vis = jax.random.normal(rng, (args.batch, cfg.n_vision_tokens,
                                      cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(lambda p, t: model.prefill(p, tokens=t,
                                                 vision_states=vis,
                                                 max_len=max_len))
    decode = jax.jit(lambda p, c, i, t: model.decode_step(p, c, i, t,
                                                          vision_states=vis))

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    generated = [tok]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        logits, cache = decode(params, cache, jnp.int32(args.prompt_len + i), tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"arch={args.arch}  batch={args.batch}")
    print(f"prefill {args.prompt_len} toks: {t_prefill * 1e3:.1f} ms "
          f"(incl. compile)")
    print(f"decode  {args.new_tokens - 1} steps: "
          f"{t_decode * 1e3 / max(args.new_tokens - 1, 1):.1f} ms/tok")
    print("generated token ids:\n", out)


if __name__ == "__main__":
    main()
