"""Batched serving: prefill a batch of prompts, decode new tokens with the
KV cache (GQA or MLA absorbed cache, per --arch smoke config).  The loop
itself lives in repro.launch.driver (shared with `python -m
repro.launch.serve`).

    PYTHONPATH=src python examples/serve_batch.py --arch qwen2-7b --new-tokens 16
"""
import argparse

from repro.configs.base import list_archs, smoke_config
from repro.launch.driver import serve_greedy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    res = serve_greedy(cfg, args.batch, args.prompt_len, args.new_tokens)

    print(f"arch={args.arch}  batch={args.batch}")
    print(f"prefill {args.prompt_len} toks: {res.prefill_s * 1e3:.1f} ms "
          f"(incl. compile)")
    print(f"decode  {args.new_tokens - 1} steps: "
          f"{res.ms_per_token:.1f} ms/tok")
    print("generated token ids:\n", res.tokens)


if __name__ == "__main__":
    main()
