"""QoI-controlled progressive retrieval (paper §6.2 / Alg 3):
retrieve three velocity components to a guaranteed V_total = Vx^2+Vy^2+Vz^2
tolerance, comparing the CP / MA / MAPE error-bound estimators.

    PYTHONPATH=src python examples/qoi_retrieval.py
"""
import numpy as np

from repro.core import qoi as qq
from repro.core import refactor as rf
from repro.core import retrieve as rt
from repro.data.fields import velocity_field


def main():
    vs = list(velocity_field((48, 48, 48), seed=1))
    truth = sum(v ** 2 for v in vs)
    refs = [rf.refactor_array(v, n) for v, n in zip(vs, ["vx", "vy", "vz"])]

    print(f"{'method':>10} {'tau':>9} {'bitrate':>8} {'iters':>6} "
          f"{'estimated':>10} {'actual':>10} guarantee")
    for tau in [1e-2, 1e-4]:
        for method, kw in [("cp", {}), ("ma", {}), ("mape", {"c": 10.0})]:
            readers = [rt.ProgressiveReader(r) for r in refs]
            res = qq.progressive_qoi_retrieve(readers, qq.V_TOTAL, tau,
                                              method=method, **kw)
            actual = np.abs(sum(v ** 2 for v in res.values) - truth).max()
            ok = actual <= res.tau_estimated <= tau
            print(f"{method:>10} {tau:9.0e} {res.bitrate:8.2f} "
                  f"{res.iterations:6d} {res.tau_estimated:10.2e} "
                  f"{actual:10.2e} {'OK' if ok else 'VIOLATED'}")


if __name__ == "__main__":
    main()
