"""QoI-controlled progressive retrieval (paper §6.2 / Alg 3) **through the
on-disk store**: write three velocity components with the dataset writer,
reopen cold, and retrieve to a guaranteed V_total = Vx^2+Vy^2+Vz^2 tolerance,
comparing the CP / MA / MAPE error-bound estimators.  Each session fetches
only the plane-group byte ranges its estimator asks for.

    PYTHONPATH=src python examples/qoi_retrieval.py
"""
import shutil
import tempfile

import numpy as np

from repro.core import qoi as qq
from repro.data.fields import velocity_field
from repro.store import DatasetStore, DatasetWriter, RetrievalService


def main():
    vs = list(velocity_field((48, 48, 48), seed=1))
    truth = sum(v ** 2 for v in vs)

    root = tempfile.mkdtemp(prefix="qoi_store_")
    try:
        _run(vs, truth, root)
    finally:
        shutil.rmtree(root, ignore_errors=True)


def _run(vs, truth, root):
    with DatasetWriter(root, chunk_elems=1 << 20) as w:
        for v, n in zip(vs, ["vx", "vy", "vz"]):
            w.write(n, v)

    store = DatasetStore.open(root)  # cold: metadata only, no payloads yet
    service = RetrievalService(store)
    print(f"store: {store.stored_bytes / 1e6:.2f} MB on disk, "
          f"variables {store.variables}")

    print(f"{'method':>10} {'tau':>9} {'bitrate':>8} {'iters':>6} "
          f"{'estimated':>10} {'actual':>10} {'MB fetched':>10} guarantee")
    for method, kw in [("cp", {}), ("ma", {}), ("mape", {"c": 10.0})]:
        session = service.open_session()  # one session per estimator
        for tau in [1e-2, 1e-4]:          # tightening tau reuses the session
            res = session.retrieve_qoi(["vx", "vy", "vz"], qq.V_TOTAL, tau,
                                       method=method, **kw)
            actual = np.abs(sum(v ** 2 for v in res.values) - truth).max()
            ok = actual <= res.tau_estimated <= tau
            print(f"{method:>10} {tau:9.0e} {res.bitrate:8.2f} "
                  f"{res.iterations:6d} {res.tau_estimated:10.2e} "
                  f"{actual:10.2e} {session.bytes_fetched / 1e6:10.2f} "
                  f"{'OK' if ok else 'VIOLATED'}")
    st = service.stats()["backend"]
    if st:
        print(f"backend: {st['bytes_fetched'] / 1e6:.2f} MB from storage, "
              f"cache hit rate {st['hit_rate']:.2f} across sessions")


if __name__ == "__main__":
    main()
