"""End-to-end training driver: a llama-style LM trained with the full
substrate — AdamW, progressive MDR checkpoints (async, atomic), bit-exact
crash resume, error-feedback gradient compression, straggler detection.

Defaults are CPU-friendly (~33M params, 60 steps).  The production-size run
the deliverable describes is:

    PYTHONPATH=src python examples/train_progressive_ckpt.py \
        --d-model 768 --n-layers 12 --steps 300      # ~103M params

At the end the script demonstrates precision-on-demand restore: bit-exact for
resume vs ~half the read bytes at rel_error=1e-2 for evaluation warm-start.
"""
import argparse
import shutil
import time

from repro.configs.base import ModelConfig
from repro.ckpt import manager as ckpt_mgr
from repro.models.model import Model, count_params
from repro.optim import adamw
from repro.train.loop import Trainer, TrainerConfig, synthetic_data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--n-layers", type=int, default=8)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_demo")
    ap.add_argument("--grad-compress-planes", type=int, default=8)
    ap.add_argument("--simulate-crash", action="store_true")
    args = ap.parse_args()

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    cfg = ModelConfig(
        name="demo-lm", family="dense", n_layers=args.n_layers,
        d_model=args.d_model, n_heads=args.d_model // 64,
        n_kv_heads=max(args.d_model // 128, 1), d_ff=4 * args.d_model,
        vocab_size=8192, compute_dtype="float32", remat=False)
    model = Model(cfg)
    print(f"model: {count_params(cfg) / 1e6:.1f}M params")

    def make_trainer():
        return Trainer(
            model,
            adamw.AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps),
            TrainerConfig(total_steps=args.steps, ckpt_every=20,
                          ckpt_dir=args.ckpt_dir, log_every=10,
                          grad_compress_planes=args.grad_compress_planes),
            synthetic_data(cfg, args.batch, args.seq))

    if args.simulate_crash:
        try:
            make_trainer().run(crash_at=args.steps // 2)
        except RuntimeError as e:
            print(f"!! {e} — restarting…")

    t0 = time.time()
    res = make_trainer().run()
    for m in res["metrics"]:
        print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:8.3f}  {m['dt'] * 1e3:7.1f} ms")
    print(f"trained to step {res['final_step']} in {time.time() - t0:.1f}s "
          f"(stragglers flagged: {res['straggler_events']})")

    # precision-on-demand restore
    step = ckpt_mgr.latest_step(args.ckpt_dir)
    like = {"params": res["params"], "opt": res["opt_state"], "ef": res["ef"]}
    _, full = ckpt_mgr.load(args.ckpt_dir, step, like)
    _, part = ckpt_mgr.load(args.ckpt_dir, step, like, rel_error=1e-2)
    print(f"restore step {step}: bit-exact read {full['bytes_read'] / 1e6:.1f} MB; "
          f"eval-precision (1e-2) read {part['bytes_read'] / 1e6:.1f} MB "
          f"({part['read_fraction']:.0%} of the checkpoint)")


if __name__ == "__main__":
    main()
