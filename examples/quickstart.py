"""Quickstart: refactor a scientific field, retrieve progressively.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import refactor as rf
from repro.core import retrieve as rt
from repro.data.fields import gaussian_field


def main():
    x = gaussian_field((64, 64, 64), slope=-2.2, seed=0)
    print(f"field: {x.shape} {x.dtype}  ({x.nbytes / 1e6:.1f} MB)")

    refd = rf.refactor_array(x, "demo")
    print(f"refactored into {len(refd.pieces)} pieces "
          f"({refd.stored_bytes / 1e6:.2f} MB stored, "
          f"{x.nbytes / refd.stored_bytes:.2f}x)")

    reader = rt.ProgressiveReader(refd)
    print(f"{'tol':>9} {'bound':>10} {'actual':>10} {'cum. bytes':>11} {'bits/val':>9}")
    for tol in [1e-1, 1e-2, 1e-3, 1e-4, 1e-5, 1e-6]:
        xh, bound, _ = reader.retrieve(tol)
        err = np.abs(xh - x).max()
        br = 8 * reader.total_bytes_fetched / x.size
        print(f"{tol:9.0e} {bound:10.2e} {err:10.2e} "
              f"{reader.total_bytes_fetched:11d} {br:9.2f}")
    print("every fetch was incremental: only new plane groups were read.")


if __name__ == "__main__":
    main()
