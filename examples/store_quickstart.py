"""Store quickstart: write a dataset, reopen cold, serve progressive
requests that fetch only delta byte ranges.

    PYTHONPATH=src python examples/store_quickstart.py [root]

Pass a directory to keep the store around; default is a temp dir.
"""
import shutil
import sys
import tempfile

import numpy as np

from repro.data.fields import gaussian_field
from repro.store import DatasetStore, DatasetWriter, RetrievalService


def main():
    keep = len(sys.argv) > 1
    root = sys.argv[1] if keep else tempfile.mkdtemp(prefix="repro_store_")
    try:
        _run(root)
    finally:
        if not keep:
            shutil.rmtree(root, ignore_errors=True)


def _run(root):
    x = gaussian_field((64, 64, 64), slope=-2.2, seed=0)

    with DatasetWriter(root, chunk_elems=1 << 17) as w:
        entry = w.write("density", x)
    print(f"wrote {root}: {len(entry.chunks)} chunks, "
          f"{entry.stored_bytes / 1e6:.2f} MB "
          f"(raw {x.nbytes / 1e6:.1f} MB)")

    store = DatasetStore.open(root)          # cold open: manifest only
    service = RetrievalService(store)
    session = service.open_session()
    print(f"{'tol':>9} {'bound':>10} {'actual':>10} {'delta B':>9} "
          f"{'total B':>9} {'% of store':>10}")
    for tol in [1e-1, 1e-2, 1e-3, 1e-4, 1e-5]:
        xh, bound, fetched = session.retrieve("density", tol)
        err = np.abs(xh - x).max()
        frac = 100.0 * session.bytes_fetched / store.stored_bytes
        print(f"{tol:9.0e} {bound:10.2e} {err:10.2e} {fetched:9d} "
              f"{session.bytes_fetched:9d} {frac:9.1f}%")
    st = service.stats()["backend"]
    print(f"backend: {st['fetches']} range reads, "
          f"{st['bytes_fetched'] / 1e6:.2f} MB moved, "
          f"hit rate {st['hit_rate']:.2f}")
    print("each request fetched only the delta plane groups (incremental).")


if __name__ == "__main__":
    main()
