"""High-concurrency serving tier: shared plane cache + coalesced decode.

``RetrievalService`` multiplexes many progressive sessions over one store,
but until this layer every session paid for its own decode: N sessions at
overlapping tolerances fetched the same byte ranges (deduplicated by the
``CachingBackend``) and then ran N identical lossless + bitplane decodes of
the same plane groups.  Under production traffic shapes — thousands of
sessions, Zipf-skewed variable popularity, tolerance-tightening bursts —
decode, not I/O, dominates, and it is perfectly shareable: a decoded plane
group is a pure function of the stored bytes.

``ServingTier`` amortizes that work across sessions with three mechanisms,
layered *above* the byte-range ``CachingBackend``:

Shared plane cache
    Decoded-on-device plane groups keyed by ``(variable, chunk, piece,
    group)`` (group ``-1`` is the piece's sign plane), byte-budgeted, LRU
    eviction with popularity-aware admission: a group only displaces cached
    entries that are less popular than itself, so one cold scan cannot
    flush the hot set.  A hit skips the backend read, the lossless decode,
    and the bitplane kernel — the session just OR-accumulates the cached
    magnitude delta into its own engine state (bit-identical: magnitude
    accumulation over disjoint bit ranges is exact, see
    ``core.reconstruct``).

Request coalescing
    Concurrent sessions wanting the same plane group register on ONE
    in-flight future (the claim table); exactly one session (the owner)
    reads the bytes and decodes, everyone else blocks on the future — the
    decode-layer generalization of ``CachingBackend._fetch_into_cache``'s
    publish-then-wake pattern, with the same failure contract: an owner's
    typed store error propagates to every coalesced waiter (each applies
    its own degrade policy) and is NEVER cached, so the next request
    retries fresh.

Cross-session batched decode
    Owners don't decode inline; they enqueue self-contained decode jobs
    and the work is drained by a combining leader: the first thread that
    needs results becomes the leader, optionally waits a small batching
    window for other sessions' jobs to arrive, then decodes a round-robin
    fair share of every tenant's queue through the same per-device
    bucketed vmapped kernels as ``reconstruct.batch_apply_pending`` — so
    pending groups from *different sessions* merge into shared kernel
    launches, and one heavy session cannot starve the others (its overflow
    jobs wait for the next round).  Any blocked thread may lead, so
    cross-owned waits can never deadlock.

See docs/serving.md for the full semantics and the load-generator
methodology (benchmarks/serving_load.py).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lossless_batch as lb
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

#: (variable, chunk, piece, group); group == -1 addresses the sign plane.
PlaneKey = Tuple[str, int, int, int]

DEFAULT_PLANE_CACHE_BYTES = 64 << 20
DEFAULT_WINDOW_S = 0.002
DEFAULT_MAX_BATCH_JOBS = 1024


# ------------------------------------------------------------------- stats --

@dataclasses.dataclass
class ServingStats:
    """Tier counters (thread-safe).  ``requests`` counts plane-group claims;
    ``plane_hits`` were served from the shared cache, ``coalesced`` by
    waiting on another session's in-flight decode, ``decoded`` are the jobs
    this tier actually ran through the kernels — their sum is ``requests``
    (every claim resolves exactly one way), so
    ``1 - decoded/requests`` is the shared-work (coalesced-read) ratio."""
    requests: int = 0
    plane_hits: int = 0
    coalesced: int = 0
    decoded: int = 0
    decode_rounds: int = 0
    decode_batches: int = 0
    window_waits: int = 0
    admitted: int = 0
    admission_rejects: int = 0
    evictions: int = 0
    errors_propagated: int = 0

    def __post_init__(self):
        self._lock = threading.Lock()

    def add(self, **kw: int) -> None:
        with self._lock:
            for k, v in kw.items():
                setattr(self, k, getattr(self, k) + v)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            out = {f.name: getattr(self, f.name)
                   for f in dataclasses.fields(self)}
        total = out["requests"]
        out["shared_ratio"] = (
            (out["plane_hits"] + out["coalesced"]) / total if total else 0.0)
        out["hit_rate"] = out["plane_hits"] / total if total else 0.0
        return out


# ----------------------------------------------------------------- futures --

@dataclasses.dataclass(frozen=True)
class DecodedPlanes:
    """One shared decode result: the device-resident magnitude delta (or
    decoded sign plane) of a single plane group.  Immutable and engine-free,
    so any number of sessions can OR it into their own state."""
    array: jax.Array
    kind: str                  # "sign" | "group"
    n_rows: int                # plane rows the group contributes (0 = sign)
    row_bytes: int             # logical plane bytes (what a decode costs)

    @property
    def nbytes(self) -> int:
        return int(self.array.size) * 4


class _Future:
    """One in-flight shared decode (publish-then-wake, as the backend's
    ``_InFlight``): ``value`` or ``error`` is set BEFORE ``event``."""
    __slots__ = ("event", "value", "error", "owner")

    def __init__(self, owner: int):
        self.event = threading.Event()
        self.value: Optional[DecodedPlanes] = None
        self.error: Optional[BaseException] = None
        self.owner = owner

    @property
    def done(self) -> bool:
        return self.event.is_set()

    def resolve(self, value: Optional[DecodedPlanes],
                error: Optional[BaseException]) -> None:
        self.value = value
        self.error = error
        self.event.set()


def entry_future(entry: Tuple[str, object]) -> _Future:
    """Uniform engine staging: ``("value", DecodedPlanes)`` (cache hit or
    already-resolved wait) wraps into a pre-resolved future; ``("future",
    fut)`` passes the live in-flight future through."""
    tag, payload = entry
    if tag != "value":
        return payload
    f = _Future(owner=-1)
    f.resolve(payload, None)
    return f


@dataclasses.dataclass
class DecodeJob:
    """A self-contained unit of shared decode work: everything needed to run
    the bitplane kernel, with no reference to any session's engine — so ANY
    thread (owner or not) can decode it and publish the result."""
    key: PlaneKey
    kind: str                  # "sign" | "group"
    rows: np.ndarray           # (P', W) uint32 host rows (sign: (1, W))
    row_offset: int            # rows above this group in the MSB-first stack
    n: int                     # piece element count
    mag_bits: int
    design: str
    backend: str
    tiles_per_block: int
    unroll: str
    device: Optional[jax.Device]
    future: _Future


# -------------------------------------------------------------- plane cache --

class PlaneCache:
    """Byte-budgeted LRU with popularity-aware admission (NOT thread-safe:
    the owning ``ServingTier`` serializes access under its lock).

    Admission mirrors TinyLFU's insight: under Zipf traffic an unbounded
    LRU lets a long tail of one-hit groups evict the hot set.  Every claim
    bumps a key's popularity count (periodically halved so the sketch ages);
    an insert may only evict victims at most as popular as itself —
    otherwise the *candidate* is rejected and the hot entry stays."""

    def __init__(self, capacity_bytes: int):
        self.capacity_bytes = int(capacity_bytes)
        self._entries: "collections.OrderedDict[PlaneKey, DecodedPlanes]" = \
            collections.OrderedDict()
        self._bytes = 0
        self._pop: Dict[PlaneKey, int] = {}
        self._pop_total = 0

    @property
    def cached_bytes(self) -> int:
        return self._bytes

    def __len__(self) -> int:
        return len(self._entries)

    def touch(self, key: PlaneKey) -> None:
        """Popularity bump (called on every claim, hit or miss)."""
        self._pop[key] = self._pop.get(key, 0) + 1
        self._pop_total += 1
        if self._pop_total > max(4096, 8 * len(self._pop)):
            # age the sketch: halve everything, drop the zeros
            self._pop = {k: v // 2 for k, v in self._pop.items() if v >= 2}
            self._pop_total = sum(self._pop.values())

    def get(self, key: PlaneKey) -> Optional[DecodedPlanes]:
        v = self._entries.get(key)
        if v is not None:
            self._entries.move_to_end(key)
        return v

    def offer(self, key: PlaneKey, value: DecodedPlanes
              ) -> Tuple[bool, int, int]:
        """Try to admit; returns (admitted, evictions, rejects)."""
        if self.capacity_bytes <= 0 or key in self._entries:
            return False, 0, 0
        self._entries[key] = value
        self._bytes += value.nbytes
        evictions = 0
        mine = self._pop.get(key, 0)
        while self._bytes > self.capacity_bytes and self._entries:
            victim = next(iter(self._entries))
            if victim == key or self._pop.get(victim, 0) > mine:
                # the LRU victim is more popular (or is the candidate
                # itself): reject the candidate instead of churning
                self._bytes -= self._entries.pop(key).nbytes
                return False, evictions, 1
            self._bytes -= self._entries.pop(victim).nbytes
            evictions += 1
        return True, evictions, 0

    def drop(self) -> None:
        self._entries.clear()
        self._bytes = 0


# ------------------------------------------------------------- serving tier --

class ServingTier:
    """Shared plane cache + claim table + combining batched decoder.

    One tier per ``RetrievalService``: all sessions of a service share one
    manifest plan per variable (same decode kernel config, same chunk ->
    device placement), which is what makes decoded plane groups exchangeable
    between them.  ``cache_bytes=0`` disables retention but keeps the
    coalescing and batching machinery (in-flight claims still dedupe)."""

    def __init__(self, cache_bytes: int = DEFAULT_PLANE_CACHE_BYTES,
                 window_s: float = DEFAULT_WINDOW_S,
                 max_batch_jobs: int = DEFAULT_MAX_BATCH_JOBS):
        self.window_s = float(window_s)
        self.max_batch_jobs = max(int(max_batch_jobs), 1)
        self.stats = ServingStats()
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._cache = PlaneCache(cache_bytes)
        self._inflight: Dict[PlaneKey, _Future] = {}
        self._jobs: Dict[int, "collections.deque[DecodeJob]"] = {}
        self._rr: "collections.deque[int]" = collections.deque()
        self._leader_active = False

    # -- claims --------------------------------------------------------------
    def claim(self, tenant: int, keys: Sequence[PlaneKey]
              ) -> Dict[PlaneKey, Tuple[str, object]]:
        """Resolve each key to ``("hit", DecodedPlanes)``, ``("mine",
        _Future)`` (this caller owns fetch+decode and MUST later ``submit``
        or ``fail`` it), or ``("theirs", _Future)`` (another session owns
        it; ``wait_for`` the future)."""
        out: Dict[PlaneKey, Tuple[str, object]] = {}
        n_hits = n_mine = n_theirs = 0
        m = obs_metrics.REGISTRY.get()
        with self._lock:
            for key in keys:
                self._cache.touch(key)
                v = self._cache.get(key)
                if v is not None:
                    out[key] = ("hit", v)
                    n_hits += 1
                    continue
                fl = self._inflight.get(key)
                if fl is not None:
                    out[key] = ("theirs", fl)
                    n_theirs += 1
                    continue
                fl = self._inflight[key] = _Future(owner=tenant)
                out[key] = ("mine", fl)
                n_mine += 1
        self.stats.add(requests=len(keys), plane_hits=n_hits,
                       coalesced=n_theirs)
        if n_hits:
            m.inc("serve.plane_cache_hits", n_hits)
        if n_theirs:
            m.inc("serve.coalesced_groups", n_theirs)
        if n_mine:
            m.inc("serve.plane_cache_misses", n_mine)
        return out

    def fail(self, key: PlaneKey, exc: BaseException) -> None:
        """Owner could not produce ``key`` (fetch failed before submit):
        propagate to every coalesced waiter, never cache."""
        with self._cv:
            fl = self._inflight.pop(key, None)
            if fl is None or fl.done:
                return
            fl.resolve(None, exc)
            self.stats.add(errors_propagated=1)
            self._cv.notify_all()

    def abandon(self, tenant: int, keys: Sequence[PlaneKey],
                exc: BaseException) -> None:
        """Owner is unwinding on an exception: fail every claimed key —
        including jobs already submitted but not yet decoded (their queue
        entries are withdrawn so no thread decodes work nobody will use)."""
        wanted = set(keys)
        with self._cv:
            q = self._jobs.get(tenant)
            if q:
                kept = [j for j in q if j.key not in wanted]
                q.clear()
                q.extend(kept)
            for key in wanted:
                fl = self._inflight.pop(key, None)
                if fl is not None and not fl.done:
                    fl.resolve(None, exc)
                    self.stats.add(errors_propagated=1)
            self._cv.notify_all()

    def should_warm(self, key: PlaneKey) -> bool:
        """Overlap-feeder filter: warming a byte range is pointless when the
        decoded group is already cached or someone is decoding it."""
        with self._lock:
            return (self._cache.get(key) is None
                    and key not in self._inflight)

    # -- decode pipeline -----------------------------------------------------
    def submit(self, tenant: int, jobs: Sequence[DecodeJob]) -> None:
        """Enqueue owned decode work (deferred: decoding happens at drain,
        batched with every other tenant's queue)."""
        if not jobs:
            return
        with self._cv:
            q = self._jobs.get(tenant)
            if q is None:
                q = self._jobs[tenant] = collections.deque()
                self._rr.append(tenant)
            q.extend(jobs)
            self._cv.notify_all()

    def wait_for(self, fut: _Future) -> DecodedPlanes:
        """Block until a coalesced future resolves, pumping the decode queue
        while waiting (a blocked waiter may lead a decode round, so two
        sessions waiting on each other's claims always make progress).
        Raises the owner's error if the shared fetch/decode failed."""
        self._pump_until([fut])
        if fut.error is not None:
            raise fut.error
        return fut.value

    def drain_engines(self, engines: Sequence) -> None:
        """Resolve and apply every engine's staged shared futures.

        Called from ``reconstruct.batch_apply_pending`` (via each engine's
        ``shared`` backref): pumps the combined queue until all futures of
        ``engines`` resolve — one leader decodes the merged, fairness-
        bounded batch — then OR-applies each result into its engine."""
        futs = [f for e in engines for (_, _, f) in e._shared_pending]
        self._pump_until(futs)
        error: Optional[BaseException] = None
        for e in engines:
            pend, e._shared_pending = list(e._shared_pending), []
            for kind, piece, fut in pend:
                if fut.error is not None:
                    error = error or fut.error
                    continue
                v = fut.value
                arr = v.array
                if e.device is not None and isinstance(arr, jax.Array) \
                        and e.device not in arr.devices():
                    arr = jax.device_put(arr, e.device)
                if kind == "sign":
                    e._apply_sign(piece, arr)
                else:
                    e._apply_mag(piece, arr, v.n_rows)
                e.bytes_decoded += v.row_bytes
        if error is not None:
            raise error

    # -- combining pump ------------------------------------------------------
    def _queued(self) -> bool:
        return any(self._jobs.values())

    def _pump_until(self, futures: Sequence[_Future]) -> None:
        while True:
            if all(f.done for f in futures):
                return
            with self._cv:
                if all(f.done for f in futures):
                    return
                if not self._queued() or self._leader_active:
                    # nothing decodable by us right now: the owners have
                    # not submitted yet, or a leader is mid-round — wait
                    # for any publish/submit and re-check
                    self._cv.wait(timeout=0.05)
                    continue
                self._leader_active = True
                wait_window = len({f.owner for f in
                                   self._inflight.values()}) > 1
            try:
                if wait_window and self.window_s > 0:
                    # batching window: other sessions' in-flight claims
                    # will land in the queue momentarily — merging them
                    # into this round shares the kernel launches
                    self.stats.add(window_waits=1)
                    time.sleep(self.window_s)
                with self._lock:
                    batch = self._take_fair_batch()
                if batch:
                    self._decode_round(batch)
            finally:
                with self._cv:
                    self._leader_active = False
                    self._cv.notify_all()

    def _take_fair_batch(self) -> List[DecodeJob]:
        """Round-robin across tenant queues, at most ``max_batch_jobs``:
        a heavy session's backlog cannot monopolize a round — everyone
        else's jobs are interleaved, overflow waits for the next round."""
        batch: List[DecodeJob] = []
        while self._rr and len(batch) < self.max_batch_jobs:
            t = self._rr.popleft()
            q = self._jobs.get(t)
            if not q:
                self._jobs.pop(t, None)
                continue
            batch.append(q.popleft())
            if q:
                self._rr.append(t)
            else:
                self._jobs.pop(t, None)
        return batch

    def _upload(self, job: DecodeJob) -> jax.Array:
        rows = np.ascontiguousarray(job.rows, dtype=np.uint32)
        if job.device is None:
            return jnp.asarray(rows)
        return jax.device_put(rows, job.device)

    def _decode_round(self, batch: List[DecodeJob]) -> None:
        """One combined decode: bucket the round's jobs exactly as
        ``reconstruct.batch_apply_pending`` does (shape/offset/kernel-config/
        device) and run one vmapped kernel launch per bucket; publish every
        result (cache admission + future resolve) before waking waiters."""
        from repro.kernels import ops as kops  # local: keep imports flat

        self.stats.add(decode_rounds=1, decoded=len(batch))
        with obs_trace.span("serve.shared_decode", jobs=len(batch)):
            groups = [j for j in batch if j.kind == "group"]
            signs = [j for j in batch if j.kind == "sign"]

            def gkey(j: DecodeJob):
                return (int(j.rows.shape[0]), int(j.rows.shape[1]),
                        j.row_offset, j.n, j.mag_bits, j.design, j.backend,
                        j.tiles_per_block, j.unroll, j.device)

            for k, pos in lb.batch_jobs(groups, gkey).items():
                n_rows, words, offset, n, mag_bits, design, bk, tiles, \
                    unroll, _dev = k
                bucket = [groups[p] for p in pos]
                try:
                    stacked = jnp.stack([self._upload(j) for j in bucket])
                    mags = kops.decode_bitplanes_offset_batch(
                        stacked, mag_bits, n, offset, design, backend=bk,
                        tiles_per_block=tiles, unroll=unroll)
                except BaseException as exc:  # noqa: BLE001 - fan error out
                    self._publish_error(bucket, exc)
                    continue
                row_bytes = 4 * n_rows * words
                self.stats.add(decode_batches=1)
                for j, mag in zip(bucket, mags):
                    self._publish(j, DecodedPlanes(mag, "group", n_rows,
                                                   row_bytes))

            def skey(j: DecodeJob):
                return (int(j.rows.shape[1]), j.n, j.design, j.backend,
                        j.tiles_per_block, j.unroll, j.device)

            for k, pos in lb.batch_jobs(signs, skey).items():
                words, n, design, bk, tiles, unroll, _dev = k
                bucket = [signs[p] for p in pos]
                try:
                    stacked = jnp.stack([self._upload(j) for j in bucket])
                    sgs = kops.decode_bitplanes_batch(
                        stacked, 1, n, design, backend=bk,
                        tiles_per_block=tiles, unroll=unroll)
                except BaseException as exc:  # noqa: BLE001
                    self._publish_error(bucket, exc)
                    continue
                row_bytes = 4 * words
                self.stats.add(decode_batches=1)
                for j, sg in zip(bucket, sgs):
                    self._publish(j, DecodedPlanes(sg, "sign", 0, row_bytes))
        obs_metrics.REGISTRY.get().inc("serve.shared_decode_jobs", len(batch))

    def _publish(self, job: DecodeJob, value: DecodedPlanes) -> None:
        with self._cv:
            self._inflight.pop(job.key, None)
            admitted, evictions, rejects = self._cache.offer(job.key, value)
            self.stats.add(admitted=int(admitted), evictions=evictions,
                           admission_rejects=rejects)
            job.future.resolve(value, None)
            self._cv.notify_all()
        m = obs_metrics.REGISTRY.get()
        if evictions:
            m.inc("serve.plane_cache_evictions", evictions)
        if rejects:
            m.inc("serve.plane_cache_admission_rejects", rejects)

    def _publish_error(self, bucket: Sequence[DecodeJob],
                       exc: BaseException) -> None:
        """A kernel-level failure poisons the whole bucket: every waiter of
        every job sees the same error; nothing is cached."""
        with self._cv:
            for j in bucket:
                self._inflight.pop(j.key, None)
                if not j.future.done:
                    j.future.resolve(None, exc)
                    self.stats.add(errors_propagated=1)
            self._cv.notify_all()

    # -- introspection -------------------------------------------------------
    def drop_cache(self) -> None:
        """Forget every cached plane group (cold-path benchmarking)."""
        with self._lock:
            self._cache.drop()

    @property
    def inflight_count(self) -> int:
        with self._lock:
            return len(self._inflight)

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            cache = {"entries": len(self._cache),
                     "bytes": self._cache.cached_bytes,
                     "capacity_bytes": self._cache.capacity_bytes}
            inflight = len(self._inflight)
        out = self.stats.snapshot()
        out["plane_cache"] = cache
        out["inflight"] = inflight
        return out
