"""Pluggable fetch backends for the progressive store.

A backend serves byte ranges by (key, offset, size), where a key is a
store-root-relative path (e.g. ``segments/vx.seg``).  Implementations:

* ``LocalFileBackend`` — pread-style range reads from files under a root
  directory (thread-safe; one file handle per key, lazily opened).
* ``InMemoryBackend``  — a dict of buffers; the writer's staging target and
  the zero-I/O test double.
* ``CachingBackend``   — wraps any backend with an LRU *segment* cache
  (keyed by exact range) plus an async prefetch queue served by worker
  threads, with hit/miss/byte accounting.  Concurrent readers of the same
  range coalesce on one in-flight fetch.

All methods are thread-safe: the RetrievalService multiplexes many sessions
over one backend.
"""
from __future__ import annotations

import collections
import dataclasses
import io
import os
import threading
from typing import Dict, Optional, Tuple

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.store import reliability as rl


@dataclasses.dataclass
class BackendStats:
    """Byte accounting (thread-safe). ``bytes_fetched`` counts only bytes
    that actually moved from the underlying storage (cache misses +
    prefetches); cache hits count toward ``bytes_served`` alone.

    ``add`` applies one event's counter deltas atomically and ``snapshot``
    reads every field under the same lock, so a snapshot taken while other
    threads serve reads is internally consistent — never e.g. a read counted
    with its served bytes missing (the historical torn-read race)."""
    reads: int = 0
    bytes_served: int = 0
    fetches: int = 0
    bytes_fetched: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    prefetch_issued: int = 0
    prefetch_useful: int = 0
    # prefetch hints shed by the bounded queue (oldest-first) under bursts
    prefetch_dropped: int = 0

    def __post_init__(self):
        self._lock = threading.Lock()

    def add(self, **kw: int) -> None:
        with self._lock:
            for k, v in kw.items():
                setattr(self, k, getattr(self, k) + v)

    @property
    def hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            out = {f.name: getattr(self, f.name)
                   for f in dataclasses.fields(self)}
        total = out["cache_hits"] + out["cache_misses"]
        out["hit_rate"] = out["cache_hits"] / total if total else 0.0
        return out


class FetchBackend:
    """Byte-range fetch interface."""

    #: True when read() results are retained (so a warming read on another
    #: thread makes the subsequent real read cheap). Plain backends discard.
    caches = False

    def read(self, key: str, offset: int, size: int) -> bytes:
        raise NotImplementedError

    def size(self, key: str) -> int:
        raise NotImplementedError

    def prefetch(self, key: str, offset: int, size: int) -> None:
        pass  # hint only; plain backends ignore it

    def close(self) -> None:
        pass


class LocalFileBackend(FetchBackend):
    def __init__(self, root: str):
        self.root = root
        self._files: Dict[str, io.BufferedReader] = {}
        self._lock = threading.Lock()

    def _path(self, key: str) -> str:
        return os.path.join(self.root, key)

    def read(self, key: str, offset: int, size: int) -> bytes:
        # pread-only: no shared seek state, safe across threads
        with self._lock:
            f = self._files.get(key)
            if f is None:
                f = open(self._path(key), "rb")
                self._files[key] = f
        data = os.pread(f.fileno(), size, offset)
        if len(data) == size:
            return data
        # pread may legally return fewer bytes than asked (signals, pipes,
        # network filesystems): loop until the range is filled, and raise a
        # TYPED truncation error on EOF — a silently-short buffer would reach
        # the decoders as subtly wrong data, not as a failure
        parts = [data]
        got = len(data)
        while got < size:
            chunk = os.pread(f.fileno(), size - got, offset + got)
            if not chunk:
                raise rl.TruncatedReadError(
                    f"truncated read: {key}@{offset}+{size} ended at "
                    f"{got} bytes (EOF inside the addressed range)")
            parts.append(chunk)
            got += len(chunk)
        return b"".join(parts)

    def size(self, key: str) -> int:
        return os.path.getsize(self._path(key))

    def close(self) -> None:
        with self._lock:
            for f in self._files.values():
                f.close()
            self._files.clear()


class InMemoryBackend(FetchBackend):
    def __init__(self, buffers: Optional[Dict[str, bytes]] = None):
        self.buffers: Dict[str, bytes] = dict(buffers or {})

    def read(self, key: str, offset: int, size: int) -> bytes:
        buf = self.buffers[key]
        if offset + size > len(buf):
            raise rl.TruncatedReadError(
                f"truncated read: {key}@{offset}+{size} beyond "
                f"{len(buf)}-byte buffer")
        return bytes(buf[offset:offset + size])

    def size(self, key: str) -> int:
        return len(self.buffers[key])


_Range = Tuple[str, int, int]


class _InFlight:
    """One coalesced fetch: waiters block on ``event``; the owner publishes
    either the cache insert or ``error`` BEFORE setting the event, so a
    failed fetch propagates to every coalesced waiter instead of wedging
    them or fanning out into a retry stampede of duplicate inner reads."""
    __slots__ = ("event", "error")

    def __init__(self):
        self.event = threading.Event()
        self.error: Optional[BaseException] = None


class CachingBackend(FetchBackend):
    """LRU segment cache + async prefetch over an inner backend."""

    caches = True

    def __init__(self, inner: FetchBackend, capacity_bytes: int = 64 << 20,
                 workers: int = 2, prefetch_queue_max: int = 512):
        self.inner = inner
        self.capacity_bytes = capacity_bytes
        self.stats = BackendStats()
        self._cache: "collections.OrderedDict[_Range, bytes]" = collections.OrderedDict()
        self._cached_bytes = 0
        self._lock = threading.Lock()
        self._inflight: Dict[_Range, _InFlight] = {}
        self._queue: "collections.deque[_Range]" = collections.deque()
        # bounded: a prefetch storm (many sessions hinting at once) must not
        # grow the queue without limit — the oldest hints are the stalest,
        # so they are shed first (counted as ``prefetch_dropped``)
        self._queue_max = max(int(prefetch_queue_max), 1)
        self._queue_cv = threading.Condition(self._lock)
        self._closed = False
        self._workers = [threading.Thread(target=self._worker, daemon=True)
                         for _ in range(max(workers, 0))]
        for w in self._workers:
            w.start()

    # -- cache mechanics (call with self._lock held) -------------------------
    def _insert(self, rng: _Range, data: bytes) -> None:
        if rng in self._cache:
            return
        self._cache[rng] = data
        self._cached_bytes += len(data)
        while self._cached_bytes > self.capacity_bytes and self._cache:
            _, old = self._cache.popitem(last=False)
            self._cached_bytes -= len(old)

    def _lookup(self, rng: _Range) -> Optional[bytes]:
        data = self._cache.get(rng)
        if data is not None:
            self._cache.move_to_end(rng)
        return data

    # -- fetch path ----------------------------------------------------------
    def _fetch_into_cache(self, rng: _Range) -> Tuple[bytes, bool]:
        """Fetch ``rng`` from the inner backend, coalescing with any other
        thread already fetching the same range.  Returns (data, performed):
        ``performed`` is True only when THIS call did the inner read.

        Failure semantics: an inner read that raises publishes its exception
        on the in-flight entry and clears the entry, so (a) every coalesced
        waiter observes the SAME error instead of re-issuing the read, and
        (b) the next caller starts a fresh fetch — errors are never cached."""
        key, off, size = rng
        while True:
            with self._lock:
                data = self._lookup(rng)
                if data is not None:
                    return data, False
                fl = self._inflight.get(rng)
                if fl is None:
                    fl = self._inflight[rng] = _InFlight()
                    owner = True
                else:
                    owner = False
            if not owner:
                fl.event.wait()
                if fl.error is not None:
                    raise fl.error
                with self._lock:
                    data = self._lookup(rng)
                if data is not None:
                    return data, False
                continue  # evicted before our lookup: loop and try to own
            try:
                data = self.inner.read(key, off, size)
            except BaseException as exc:
                # publish-then-wake ordering: waiters read fl.error after
                # event.wait(), so the error must be set before event.set()
                fl.error = exc
                with self._lock:
                    self._inflight.pop(rng, None)
                fl.event.set()
                raise
            # insert BEFORE waking waiters, so coalesced readers find the
            # data in cache instead of re-reading the range themselves.
            self.stats.add(fetches=1, bytes_fetched=size)
            with self._lock:
                self._insert(rng, data)
                self._inflight.pop(rng, None)
            fl.event.set()
            return data, True

    def read(self, key: str, offset: int, size: int) -> bytes:
        rng = (key, offset, size)
        m = obs_metrics.REGISTRY.get()
        with self._lock:
            data = self._lookup(rng)
        hit = data is not None
        self.stats.add(reads=1, bytes_served=size,
                       **({"cache_hits": 1} if hit else {"cache_misses": 1}))
        obs_trace.event(obs_trace.EV_BACKEND_READ, key=key, bytes=size,
                        hit=hit)
        m.inc("backend.bytes_served", size)
        m.inc("backend.cache_hits" if hit else "backend.cache_misses")
        if hit:
            return data
        data, performed = self._fetch_into_cache(rng)
        if performed:
            m.inc("backend.bytes_fetched", size)
        return data

    def size(self, key: str) -> int:
        return self.inner.size(key)

    # -- prefetch ------------------------------------------------------------
    def prefetch(self, key: str, offset: int, size: int) -> None:
        if not self._workers:
            return
        rng = (key, offset, size)
        dropped = 0
        with self._queue_cv:
            if self._closed or rng in self._cache or rng in self._inflight:
                return
            self._queue.append(rng)
            while len(self._queue) > self._queue_max:
                self._queue.popleft()  # shed the stalest hint first
                dropped += 1
            self._queue_cv.notify()
        self.stats.add(prefetch_issued=1, prefetch_dropped=dropped)
        if dropped:
            obs_metrics.REGISTRY.get().inc("backend.prefetch_dropped",
                                           dropped)

    def _worker(self) -> None:
        # the worker must survive ANY per-item failure: prefetch is a hint,
        # and a dead worker silently degrades every future prefetch.  Only
        # the shutdown path (self._closed) exits the loop.
        while True:
            try:
                with self._queue_cv:
                    while not self._queue and not self._closed:
                        self._queue_cv.wait()
                    if self._closed:
                        return
                    rng = self._queue.popleft()
                _, performed = self._fetch_into_cache(rng)
                if performed:  # the prefetch itself moved the bytes
                    self.stats.add(prefetch_useful=1)
            except Exception:  # noqa: BLE001 - prefetch is best-effort
                pass

    def drop_cache(self) -> None:
        """Forget all cached segments (cold-cache benchmarking)."""
        with self._lock:
            self._cache.clear()
            self._cached_bytes = 0

    def close(self) -> None:
        with self._queue_cv:
            self._closed = True
            self._queue.clear()
            self._queue_cv.notify_all()
        for w in self._workers:
            w.join(timeout=1.0)
        self.inner.close()
