"""Fault tolerance for store I/O: error taxonomy, checksums, retries, chaos.

The store's byte-range read path ("guaranteed error control") is only as
trustworthy as the I/O under it.  This module is the reliability layer the
rest of ``repro.store`` threads through:

* **Taxonomy** — every failure a backend read can surface is typed:
  ``TransientFetchError`` (retryable: flaky I/O, timeouts),
  ``CorruptSegmentError`` (data at rest does not match what the manifest
  recorded — NOT retryable; subclasses ``ValueError`` so pre-existing
  ``Segment.from_bytes`` error contracts still hold),
  ``TruncatedReadError`` (a read ended short of the addressed range),
  ``FatalStoreError`` (missing key/file, programming errors — never retry),
  ``UnreachableSegmentError`` (retries/deadline/circuit-breaker exhausted;
  the *degradation* signal the read path may convert into a wider bound).

* **Integrity** — ``checksum()`` is the store's checksum function (CRC-32,
  ``zlib.crc32``: C-speed and stdlib-only — the container has no CRC32C
  extension and a pure-Python Castagnoli table would blow the <3% overhead
  budget).  Writers record it per (chunk, piece, group) blob in the manifest
  (``GroupRef.crc``) and over the manifest's own ``variables`` body
  (``manifest.json`` key ``"crc32"``); readers verify on every segment read
  (``verify_checksum``).  Both fields are backward/forward compatible:
  absent means unchecked, extra is ignored by old readers — the same
  evolution rules as the ``shards``/``plan`` manifest fields.

* **Resilience** — ``RetryingBackend`` wraps any fetch backend with bounded
  exponential backoff + full jitter, a per-read deadline, and a per-key
  circuit breaker, instrumented as ``repro.obs`` metrics
  (``backend.retries``, ``backend.breaker_open``, span
  ``backend.retry_wait``).  Compose it UNDER ``CachingBackend`` so retries
  coalesce with in-flight reads: ``CachingBackend(RetryingBackend(inner))``.

* **Chaos** — ``FaultInjectionBackend`` is the deterministic fault harness:
  per-visit transient faults and slow reads, plus *sticky* (at-rest)
  corruption/truncation that survives retries, all drawn from a seeded hash
  of (key, offset, size) so concurrent test runs are reproducible.
  ``chaos_from_env`` lets CI wrap every default-constructed store backend
  via ``REPRO_CHAOS=transient=0.05,seed=1234`` without touching test code.

Degradation policy (the fourth pillar) lives where the state is: the read
side (``core.retrieve.ProgressiveReader`` / ``store.service``) catches
``StoreIOError`` per plane group and serves the reconstruction *without*
the unreachable group, returning the honestly widened bound.  See
docs/reliability.md.
"""
from __future__ import annotations

import dataclasses
import os
import random
import threading
import time
import zlib
from typing import Callable, Dict, Optional, Tuple

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


# ------------------------------------------------------------------ errors --

class StoreIOError(Exception):
    """Base of every typed store I/O failure."""


class TransientFetchError(StoreIOError):
    """A read failed in a way a retry may fix (flaky I/O, timeout)."""


class CorruptSegmentError(StoreIOError, ValueError):
    """Bytes at rest do not match what the manifest recorded (checksum
    mismatch, bad framing).  Subclasses ValueError: the pre-checksum decode
    path already raised ValueError on corrupt framing, and callers that
    handle that keep working."""


class TruncatedReadError(CorruptSegmentError):
    """A read ended before the addressed range did (EOF inside the range)."""


class FatalStoreError(StoreIOError):
    """Non-retryable failure: missing key/file, closed backend, bad usage."""


class UnreachableSegmentError(StoreIOError):
    """Retries, deadline, or circuit breaker exhausted for a byte range.
    This is the signal degradation policies convert into a wider bound."""


#: Exception types a retry may fix.  OSError covers real I/O flakiness
#: (EIO, EAGAIN, network filesystems); FileNotFoundError is carved out as
#: fatal in ``classify`` — retrying a missing file never helps.
_TRANSIENT_TYPES = (TransientFetchError, TimeoutError, ConnectionError,
                    InterruptedError, BlockingIOError)


def classify(exc: BaseException) -> str:
    """Map an exception to its retry class: 'transient' | 'corrupt' | 'fatal'."""
    if isinstance(exc, CorruptSegmentError):
        return "corrupt"
    if isinstance(exc, (FatalStoreError, FileNotFoundError, KeyError,
                        NotImplementedError)):
        return "fatal"
    if isinstance(exc, _TRANSIENT_TYPES) or isinstance(exc, OSError):
        return "transient"
    return "fatal"


# --------------------------------------------------------------- integrity --

def checksum(data: bytes) -> int:
    """The store's integrity checksum: CRC-32 over the blob (uint32)."""
    return zlib.crc32(data) & 0xFFFFFFFF


def verify_checksum(blob: bytes, expected: int, context: str = "") -> None:
    """Raise ``CorruptSegmentError`` (and count it) unless ``blob`` matches."""
    got = checksum(blob)
    if got != (expected & 0xFFFFFFFF):
        obs_metrics.REGISTRY.get().inc("backend.checksum_failures")
        raise CorruptSegmentError(
            f"checksum mismatch{f' for {context}' if context else ''}: "
            f"stored crc32=0x{expected & 0xFFFFFFFF:08x}, "
            f"computed 0x{got:08x} over {len(blob)} bytes")


def manifest_body_checksum(variables_json: Dict) -> int:
    """CRC-32 over the canonical serialization of a manifest's ``variables``
    value.  Canonical = ``json.dumps(..., sort_keys=True)`` with default
    separators, which round-trips bit-identically through parse + re-dump —
    so a reader can verify the checksum from the *parsed* manifest without
    keeping the raw file bytes around, and a newer writer's extra keys are
    covered by the checksum it computed itself (forward compatible)."""
    import json
    return checksum(json.dumps(variables_json, sort_keys=True).encode())


# ------------------------------------------------------------------ retry ---

@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff + full jitter, deadline, circuit breaker.

    Sleep before attempt ``k`` (k >= 1) is drawn uniformly from
    ``[base/2, base] * 2^(k-1)``, capped at ``max_delay_s`` — full jitter
    keeps coalesced retries from stampeding in lockstep.  A read that would
    sleep past ``deadline_s`` raises ``UnreachableSegmentError`` instead.
    ``breaker_threshold`` consecutive exhausted reads on one key open that
    key's circuit for ``breaker_reset_s``: reads fail fast (no backend
    traffic) until the window passes, then one probe read half-opens it."""
    attempts: int = 4
    base_delay_s: float = 0.05
    max_delay_s: float = 2.0
    deadline_s: float = 30.0
    breaker_threshold: int = 5
    breaker_reset_s: float = 30.0


@dataclasses.dataclass
class RetryStats:
    reads: int = 0
    retries: int = 0
    transient_errors: int = 0
    corrupt_errors: int = 0
    fatal_errors: int = 0
    exhausted: int = 0
    breaker_opens: int = 0
    breaker_fast_fails: int = 0

    def snapshot(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class _Breaker:
    """Per-key circuit breaker state (guarded by RetryingBackend._lock)."""
    __slots__ = ("failures", "opened_at")

    def __init__(self):
        self.failures = 0
        self.opened_at: Optional[float] = None


class RetryingBackend:
    """Typed-retry wrapper around any fetch backend (duck-typed: ``read``,
    ``size``, ``prefetch``, ``close``).

    Only *transient* failures are retried; corruption is a property of the
    bytes at rest (a re-read returns the same bytes) and fatal errors never
    improve, so both raise immediately with their type intact.  ``clock``
    and ``sleep`` are injectable for tests.
    """

    caches = False  # retries don't retain bytes; wrap in CachingBackend for that

    def __init__(self, inner, policy: RetryPolicy = RetryPolicy(),
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 rng: Optional[random.Random] = None):
        self.inner = inner
        self.policy = policy
        self.stats = RetryStats()
        self._clock = clock
        self._sleep = sleep
        self._rng = rng if rng is not None else random.Random()
        self._lock = threading.Lock()
        self._breakers: Dict[str, _Breaker] = {}

    # -- circuit breaker -----------------------------------------------------
    def _breaker(self, key: str) -> _Breaker:
        b = self._breakers.get(key)
        if b is None:
            b = self._breakers[key] = _Breaker()
        return b

    def _check_breaker(self, key: str) -> None:
        with self._lock:
            b = self._breaker(key)
            if b.opened_at is None:
                return
            if self._clock() - b.opened_at >= self.policy.breaker_reset_s:
                # half-open: let this read probe; failure re-opens below
                b.opened_at = None
                b.failures = self.policy.breaker_threshold - 1
                return
            self.stats.breaker_fast_fails += 1
        obs_metrics.REGISTRY.get().inc("backend.breaker_fast_fails")
        raise UnreachableSegmentError(
            f"circuit open for {key!r}: {self.policy.breaker_threshold} "
            f"consecutive failed reads; retrying after "
            f"{self.policy.breaker_reset_s}s")

    def _record_outcome(self, key: str, ok: bool) -> None:
        with self._lock:
            b = self._breaker(key)
            if ok:
                b.failures = 0
                b.opened_at = None
                return
            b.failures += 1
            if (b.failures >= self.policy.breaker_threshold
                    and b.opened_at is None):
                b.opened_at = self._clock()
                self.stats.breaker_opens += 1
                obs_metrics.REGISTRY.get().inc("backend.breaker_open", key=key)

    # -- retry loop ----------------------------------------------------------
    def _run(self, key: str, what: str, fn):
        self._check_breaker(key)
        m = obs_metrics.REGISTRY.get()
        with self._lock:
            self.stats.reads += 1
        t0 = self._clock()
        last: Optional[BaseException] = None
        for attempt in range(1, self.policy.attempts + 1):
            try:
                out = fn()
            except BaseException as exc:  # noqa: BLE001 - classified below
                kind = classify(exc)
                with self._lock:
                    if kind == "transient":
                        self.stats.transient_errors += 1
                    elif kind == "corrupt":
                        self.stats.corrupt_errors += 1
                    else:
                        self.stats.fatal_errors += 1
                if kind == "corrupt":
                    self._record_outcome(key, ok=False)
                    raise
                if kind == "fatal":
                    # fatal does NOT trip the breaker: a missing key says
                    # nothing about the health of the path to other keys
                    raise
                last = exc
                if attempt >= self.policy.attempts:
                    break
                delay = min(self.policy.base_delay_s * (2 ** (attempt - 1)),
                            self.policy.max_delay_s)
                delay *= 0.5 + 0.5 * self._rng.random()  # full jitter
                if self._clock() - t0 + delay > self.policy.deadline_s:
                    break
                with self._lock:
                    self.stats.retries += 1
                m.inc("backend.retries", key=key)
                with obs_trace.span("backend.retry_wait", key=key,
                                    attempt=attempt, delay_s=round(delay, 4)):
                    self._sleep(delay)
                continue
            self._record_outcome(key, ok=True)
            return out
        self._record_outcome(key, ok=False)
        with self._lock:
            self.stats.exhausted += 1
        m.inc("backend.reads_exhausted")
        raise UnreachableSegmentError(
            f"{what} failed after {self.policy.attempts} attempts "
            f"({self._clock() - t0:.3f}s): {last!r}") from last

    # -- FetchBackend surface ------------------------------------------------
    def read(self, key: str, offset: int, size: int) -> bytes:
        return self._run(key, f"read {key}@{offset}+{size}",
                         lambda: self.inner.read(key, offset, size))

    def size(self, key: str) -> int:
        return self._run(key, f"size {key}", lambda: self.inner.size(key))

    def prefetch(self, key: str, offset: int, size: int) -> None:
        self.inner.prefetch(key, offset, size)  # hint only; never retried

    def close(self) -> None:
        self.inner.close()


# -------------------------------------------------------- fault injection ---

@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Fault mix for ``FaultInjectionBackend`` (rates are per-read draws).

    ``transient`` and ``slow`` are *per-visit*: a retry of the same range
    redraws.  ``corrupt`` and ``truncate`` are *sticky* (at-rest): the
    decision is a pure function of (seed, key, offset, size), so a corrupted
    range stays corrupted across retries and across backend instances with
    the same seed — exactly how real bit rot behaves."""
    transient: float = 0.0
    corrupt: float = 0.0
    truncate: float = 0.0
    slow: float = 0.0
    slow_s: float = 0.005
    seed: int = 0
    # keys never injected (e.g. protect the manifest when a test targets
    # segment reads only); substring match against the backend key
    protect: Tuple[str, ...] = ()


@dataclasses.dataclass
class FaultStats:
    reads: int = 0
    transient_injected: int = 0
    corrupt_injected: int = 0
    truncate_injected: int = 0
    slow_injected: int = 0

    def snapshot(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class FaultInjectionBackend:
    """Deterministic chaos double over any fetch backend.

    Determinism contract: the fault decision for the N-th visit of a given
    (key, offset, size) range depends only on (seed, key, offset, size, N) —
    never on global call order — so multi-threaded test runs reproduce."""

    caches = False

    def __init__(self, inner, faults: FaultConfig = FaultConfig()):
        self.inner = inner
        self.faults = faults
        self.stats = FaultStats()
        self._lock = threading.Lock()
        self._visits: Dict[Tuple[str, int, int], int] = {}

    def _protected(self, key: str) -> bool:
        return any(p in key for p in self.faults.protect)

    @staticmethod
    def _draw(seed_parts: Tuple) -> random.Random:
        return random.Random(hash(seed_parts) & 0xFFFFFFFFFFFF)

    def read(self, key: str, offset: int, size: int) -> bytes:
        f = self.faults
        with self._lock:
            self.stats.reads += 1
            n = self._visits[(key, offset, size)] = \
                self._visits.get((key, offset, size), 0) + 1
        if not self._protected(key):
            visit = self._draw((f.seed, "visit", key, offset, size, n))
            if visit.random() < f.transient:
                with self._lock:
                    self.stats.transient_injected += 1
                raise TransientFetchError(
                    f"injected transient fault: {key}@{offset}+{size} "
                    f"(visit {n})")
            if visit.random() < f.slow:
                with self._lock:
                    self.stats.slow_injected += 1
                time.sleep(f.slow_s)
        data = self.inner.read(key, offset, size)
        if self._protected(key):
            return data
        sticky = self._draw((f.seed, "persist", key, offset, size))
        if sticky.random() < f.corrupt and len(data) > 0:
            with self._lock:
                self.stats.corrupt_injected += 1
            buf = bytearray(data)
            pos = sticky.randrange(len(buf))
            buf[pos] ^= 1 << sticky.randrange(8)
            return bytes(buf)
        if sticky.random() < f.truncate and len(data) > 0:
            with self._lock:
                self.stats.truncate_injected += 1
            return data[:sticky.randrange(len(data))]
        return data

    def size(self, key: str) -> int:
        return self.inner.size(key)

    def prefetch(self, key: str, offset: int, size: int) -> None:
        self.inner.prefetch(key, offset, size)

    def close(self) -> None:
        self.inner.close()


# ------------------------------------------------------------- chaos hook ---

#: Environment knob the CI chaos job sets to run ORDINARY test suites under
#: injected faults: every DatasetStore.open() with a default backend wraps
#: its file backend in FaultInjectionBackend + RetryingBackend.  Format is
#: comma-separated k=v pairs, e.g. ``transient=0.05,seed=1234``; recognized
#: keys: transient, corrupt, truncate, slow, slow_s, seed, attempts,
#: base_delay, max_delay.  Retry delays default fast (5ms base) so suites
#: stay quick.
CHAOS_ENV = "REPRO_CHAOS"


def chaos_from_env(inner, env: Optional[str] = None):
    """Wrap ``inner`` per the ``REPRO_CHAOS`` env var; identity when unset."""
    spec = os.environ.get(CHAOS_ENV) if env is None else env
    if not spec:
        return inner
    kv: Dict[str, float] = {}
    for part in spec.split(","):
        if not part.strip():
            continue
        k, _, v = part.partition("=")
        kv[k.strip()] = float(v) if v else 1.0
    faults = FaultConfig(
        transient=kv.get("transient", 0.0),
        corrupt=kv.get("corrupt", 0.0),
        truncate=kv.get("truncate", 0.0),
        slow=kv.get("slow", 0.0),
        slow_s=kv.get("slow_s", 0.005),
        seed=int(kv.get("seed", 0)))
    policy = RetryPolicy(
        attempts=int(kv.get("attempts", 6)),
        base_delay_s=kv.get("base_delay", 0.005),
        max_delay_s=kv.get("max_delay", 0.05),
        deadline_s=kv.get("deadline", 30.0))
    return RetryingBackend(FaultInjectionBackend(inner, faults), policy,
                           rng=random.Random(int(kv.get("seed", 0))))
