"""Chunked dataset writer: arrays -> refactor pipeline -> addressable store.

``DatasetWriter`` drives ``core.refactor.refactor_array`` through the
``ChunkedRefactorPipeline`` (copy/compute/serialize overlap) with a custom
sink that appends each chunk's segments to the variable's segment file and
records their byte ranges — so writing a larger-than-memory array streams
chunk by chunk and never holds more than the pipeline's queue depth.

The manifest is written atomically (tmp + rename) on ``finalize()``/context
exit, so a crashed write never leaves a store that parses but dangles.
"""
from __future__ import annotations

import json
import logging
import os
from typing import List, Optional

import numpy as np

from repro.core import decompose as dc
from repro.core import lossless as ll
from repro.core import pipeline as pl
from repro.core import refactor as rf
from repro.core import sharded as shd
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.store import layout as lo
from repro import tune as tn

logger = logging.getLogger("repro.store")


class _SegmentFileWriter:
    """Appending writer for one variable's segment file."""

    def __init__(self, path: str):
        os.makedirs(os.path.dirname(path), exist_ok=True)
        self._f = open(path, "wb")
        self._off = 0

    def write(self, blob: bytes) -> int:
        off = self._off
        self._f.write(blob)
        self._off += len(blob)
        return off

    def close(self) -> None:
        self._f.flush()
        self._f.close()


class DatasetWriter:
    """Write variables into a progressive store directory.

        with DatasetWriter("/data/run42", chunk_elems=1 << 20) as w:
            w.write("vx", vx)
            w.write("vy", vy)
        store = DatasetStore.open("/data/run42")

    One variable = one segment file; chunks, pieces and plane groups land at
    recorded offsets.  ``levels=None`` picks the decomposition depth from the
    (flattened) chunk length per variable.
    """

    def __init__(self, root: str, chunk_elems: int = 1 << 20,
                 levels: Optional[int] = None,
                 design: Optional[str] = None,
                 mag_bits: Optional[int] = None,
                 hybrid: Optional[ll.HybridConfig] = None,
                 pipelined: bool = True, backend: Optional[str] = None,
                 fused: bool = True, dispatch_ahead: Optional[int] = None,
                 mesh: shd.MeshLike = None,
                 config: Optional[tn.RefactorConfig] = None,
                 use_tune_cache: bool = True,
                 checksums: bool = True):
        self.root = root
        self.chunk_elems = int(chunk_elems)
        self.levels = levels
        # knob resolution happens per write() in ChunkedRefactorPipeline
        # (explicit kwargs > config= > cached autotuned winner > defaults);
        # the writer just forwards, then records the pipeline's EFFECTIVE
        # config as the variable's manifest ``plan`` so readers replay it.
        self.design = design
        self.mag_bits = mag_bits
        self.hybrid = hybrid
        self.pipelined = pipelined
        self.backend = backend
        # fused one-dispatch write engine + per-device in-flight encode
        # depth: the pipelined write keeps dispatch_ahead chunks queued per
        # mesh device and drains whole windows through one batched finish
        # (see core.refactor_fused.finish_encode_many / docs/distributed.md)
        self.fused = fused
        self.dispatch_ahead = dispatch_ahead
        self.config = config
        self.use_tune_cache = use_tune_cache
        # per-(chunk, piece, group) CRCs in the manifest; False writes a
        # pre-integrity store (old readers are unaffected either way)
        self.checksums = checksums
        # mesh-sharded write (core.sharded): chunks round-robin across the
        # mesh's devices; the chunk -> shard map is recorded per variable in
        # the manifest.  Payload bytes are placement-independent (the
        # single-device-oracle guarantee, docs/distributed.md).
        self.mesh = shd.resolve_mesh(mesh)
        self._finalized = False
        self._written: set = set()
        os.makedirs(root, exist_ok=True)
        # start from the committed manifest (if any), so writing into an
        # existing store adds/replaces variables instead of dropping the rest
        committed = os.path.join(root, lo.MANIFEST_NAME)
        if os.path.exists(committed):
            with open(committed) as f:
                self.manifest = lo.Manifest.from_json(json.load(f))
        else:
            self.manifest = lo.Manifest()

    # ------------------------------------------------------------- writing --
    def write(self, name: str, x: np.ndarray) -> lo.VariableEntry:
        if self._finalized:
            raise RuntimeError("writer already finalized")
        if not name or "/" in name or name.startswith("."):
            raise ValueError(f"invalid variable name {name!r}")
        # duplicate names within one writer session are an error (a second
        # write would silently replace the first's manifest entry and orphan
        # its segments); a name only present in the COMMITTED manifest is a
        # REWRITE — the new generation replaces it when finalize() commits
        if name in self._written:
            raise ValueError(f"variable {name!r} already written")
        x = np.asarray(x, dtype=np.float32)
        shape = tuple(int(s) for s in x.shape)
        # NB: ascontiguousarray promotes 0-d to 1-d, hence shape captured first
        flat = np.ascontiguousarray(x).reshape(-1)
        levels = self.levels
        if levels is None:
            levels = dc.num_levels((min(self.chunk_elems, max(flat.size, 1)),))
        chunks: List[lo.ChunkEntry] = []
        # per-write generation token: rewriting an existing store never
        # truncates a file the currently-committed manifest addresses
        seg_key = lo.segment_key(name, generation=os.urandom(4).hex())
        seg_writer = _SegmentFileWriter(lo.segment_path(self.root, seg_key))

        def sink(ci: int, refd: rf.Refactored) -> bytes:
            # chunks reach the sink in index order (pipeline contract), so
            # append order == chunk order and offsets are deterministic.
            chunks.append(lo.chunk_entry_from_refactored(
                refd, seg_writer.write, checksums=self.checksums))
            return b""  # the pipeline's blob list is unused on this path

        pipe = pl.ChunkedRefactorPipeline(
            chunk_elems=self.chunk_elems, pipelined=self.pipelined,
            levels=levels, design=self.design, hybrid=self.hybrid,
            backend=self.backend, mag_bits=self.mag_bits, sink=sink,
            fused=self.fused, dispatch_ahead=self.dispatch_ahead,
            mesh=self.mesh, config=self.config,
            use_tune_cache=self.use_tune_cache)
        try:
            with obs_trace.span("store.write", var=name):
                pipe.refactor(flat, name=name)
        finally:
            seg_writer.close()

        # manifest fields record the EFFECTIVE knobs the pipeline resolved
        # (legacy kwargs > config= > tune cache > defaults), and ``plan``
        # captures the full config so readers replay the tuned plan
        entry = lo.VariableEntry(
            name=name, shape=shape, levels=levels,
            design=pipe.design,
            mag_bits=pipe.config.resolved_mag_bits(),
            group_size=pipe.hybrid.group_size, chunk_elems=self.chunk_elems,
            segment_file=seg_key,
            amax=float(np.abs(x).max()) if x.size else 0.0,
            range=float(x.max() - x.min()) if x.size else 0.0,
            chunks=chunks,
            shards=(pipe.chunk_shards(len(chunks))
                    if self.mesh is not None else None),
            plan=pipe.config.to_json())
        self.manifest.variables[name] = entry
        self._written.add(name)
        # compression accounting: raw input bytes vs bytes landed in the
        # segment file (payloads + per-group headers).  ratio >= 1 is a win.
        raw, stored = int(flat.nbytes), int(entry.stored_bytes)
        m = obs_metrics.REGISTRY.get()
        m.inc("store.bytes_raw", raw, var=name)
        m.inc("store.bytes_stored", stored, var=name)
        if stored:
            m.gauge("store.compression_ratio", raw / stored, var=name)
        if stored > raw:
            logger.warning(
                "store write of %r EXPANDED the data: stored %d bytes for "
                "%d raw bytes (ratio %.3f < 1.0) — the lossless stage is "
                "losing to the bitplane/group framing on this input; see "
                "docs/observability.md#compression-accounting", name,
                stored, raw, raw / max(stored, 1))
        return entry

    # ----------------------------------------------------------- finalize --
    def finalize(self) -> str:
        if self._finalized:
            return os.path.join(self.root, lo.MANIFEST_NAME)
        path = os.path.join(self.root, lo.MANIFEST_NAME)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.manifest.to_json(), f)
        os.replace(tmp, path)
        self._finalized = True
        return path

    def __enter__(self) -> "DatasetWriter":
        return self

    def __exit__(self, exc_type, *exc) -> None:
        if exc_type is None:
            self.finalize()
