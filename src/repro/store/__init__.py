"""repro.store — persistent progressive data store + retrieval service.

The write path chunks an array through the refactor pipeline and lays the
losslessly-encoded plane-group segments out on disk with per-(chunk, piece,
group) byte-range addressing (layout).  The read path opens the manifest
(metadata only), plans greedy rate allocation against recorded segment
sizes, and fetches exactly the delta byte ranges through a pluggable,
caching, prefetching backend — multiplexed over many concurrent sessions by
the RetrievalService.

    writer.DatasetWriter   refactor_array -> pipeline -> segments + manifest
    layout.DatasetStore    manifest + byte-range addressing
    backend.*              local-file / in-memory fetch, LRU cache, prefetch
    service.RetrievalService   sessions, batched decode, QoI serving
    serving.ServingTier    shared plane cache, coalescing, batched decode
    reliability.*          checksums, typed errors, retries, fault injection
"""
from repro.store.backend import (BackendStats, CachingBackend, FetchBackend,
                                 InMemoryBackend, LocalFileBackend)
from repro.store.serving import (DecodedPlanes, PlaneCache, ServingStats,
                                 ServingTier)
from repro.store.layout import (ChunkEntry, DatasetStore, GroupRef,
                                Manifest, PieceEntry, VariableEntry)
from repro.store.reliability import (CorruptSegmentError, FatalStoreError,
                                     FaultConfig, FaultInjectionBackend,
                                     RetryingBackend, RetryPolicy,
                                     StoreIOError, TransientFetchError,
                                     TruncatedReadError,
                                     UnreachableSegmentError)
from repro.store.service import RetrievalService, StoreSegmentSource
from repro.store.writer import DatasetWriter

__all__ = [
    "BackendStats", "CachingBackend", "FetchBackend", "InMemoryBackend",
    "LocalFileBackend", "ChunkEntry", "DatasetStore", "GroupRef", "Manifest",
    "PieceEntry", "VariableEntry", "RetrievalService", "StoreSegmentSource",
    "DatasetWriter", "CorruptSegmentError", "FatalStoreError", "FaultConfig",
    "FaultInjectionBackend", "RetryingBackend", "RetryPolicy", "StoreIOError",
    "TransientFetchError", "TruncatedReadError", "UnreachableSegmentError",
    "DecodedPlanes", "PlaneCache", "ServingStats", "ServingTier",
]
