"""Retrieval service: many concurrent progressive sessions over one store.

Layering (read path)::

    RetrievalService
      └─ Session (per client; state = groups already shipped per variable)
           └─ StoreVariableReader (per variable; one ProgressiveReader per
              stored chunk, fed by StoreSegmentSource byte-range fetches)

Serving a request runs in two stages mapped onto the core pipeline's overlap
primitive (``core.pipeline.overlap_map``): the feeder thread *warms* the
backend cache with exactly the delta byte ranges the greedy plan needs
(I/O), while the caller thread runs lossless decompress + bitplane decode
(compute).  Every chunk reader owns a device-resident incremental
reconstruction engine (``core.reconstruct``), so serving decodes only the
*delta* plane groups a request fetched: ``reconstruct_many`` drains the
staged groups of every engine in the batch and decodes each same-shaped
(rows, words, n, offset) bucket — across chunks, variables, and sessions —
through one vmapped kernel call, which is where multi-session serving wins
over running each reader alone.

Both max-norm (``Session.retrieve``) and QoI (``Session.retrieve_qoi``)
requests are incremental: repeating a request with a tighter tolerance
fetches (and decodes) only the additional plane groups.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline as pl
from repro.core import qoi as qq
from repro.core import sharded as shd
from repro.core.retrieve import ProgressiveReader, SegmentSource
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.store import layout as lo
from repro.store import serving as sv
from repro import tune as tn


class StoreSegmentSource(SegmentSource):
    """Resolves (piece, group) to byte-range reads on a store backend."""

    def __init__(self, store: lo.DatasetStore, var: str, chunk: int):
        self._store = store
        self._var = var
        self._pieces = store.variable(var).chunks[chunk].pieces

    def _ref(self, piece: int, group: int) -> lo.GroupRef:
        p = self._pieces[piece]
        return p.sign if group < 0 else p.groups[group]

    def sign(self, piece: int):
        return self._store.read_segment(self._var, self._ref(piece, -1))

    def group(self, piece: int, group: int):
        return self._store.read_segment(self._var, self._ref(piece, group))

    def prefetch(self, wants: List[Tuple[int, int]]) -> None:
        for piece, group in wants:
            self._store.prefetch_segment(self._var, self._ref(piece, group))

    def warm(self, wants: List[Tuple[int, int]]) -> int:
        """Synchronously pull the ranges into the backend cache (the overlap
        feeder's I/O stage).  No-op on cache-less backends, where the read
        would be discarded and the real fetch would re-issue it.  Best-effort:
        a failing range is skipped — warming is a cache hint, and the real
        fetch in ``_fetch_to`` is where failure policy (retry exhaustion,
        degradation) is decided.  Returns bytes read."""
        if not getattr(self._store.backend, "caches", False):
            return 0
        total = 0
        for piece, group in wants:
            ref_ = self._ref(piece, group)
            try:
                self._store.backend.read(
                    self._store.variable(self._var).segment_file,
                    ref_.offset, ref_.size)
            except Exception:  # noqa: BLE001 - warming is best-effort
                continue
            total += ref_.size
        return total


# ------------------------------------------------------------ batched decode --

def reconstruct_many(readers: Sequence[ProgressiveReader],
                     backend: str = "auto") -> List[Tuple[jax.Array, float]]:
    """Decode + recompose many readers, batching same-shaped *delta* decodes.

    Each incremental reader's engine holds the newly fetched, still-undecoded
    plane groups; ``reconstruct.batch_apply_pending`` decodes every
    same-shaped (rows, words, n, offset) bucket — across pieces, chunks,
    variables, and sessions — through ONE vmapped
    ``kernels.ops.decode_bitplanes_offset_batch`` call (grouping shared with
    the codec engine via ``lossless_batch.batch_jobs``).  Mesh-sharded
    readers drain per device (``core.sharded``): buckets never mix devices,
    each launch runs where its engine state lives.  Unlike the old
    cross-session *full* decode, already-decoded state is never re-run:
    clean engines serve their cached reconstruction.  Returns
    [(device array, bound)] aligned with ``readers``; oracle
    (``incremental=False``) readers fall back to their own full decode."""
    shd.ShardedReconstructEngine.drain(
        [r.engine for r in readers if r.incremental])
    return [r.reconstruct_device() for r in readers]


# ------------------------------------------------------------ variable reader --

class _VarRef:
    """Facade matching the slice of ``Refactored`` the QoI loop touches."""

    def __init__(self, var: lo.VariableEntry, readers: List[ProgressiveReader]):
        self.data_amax = var.amax
        self.data_range = var.range
        self.shape = var.shape
        self.n_elements = var.n_elements
        self.pieces = [pm for r in readers for pm in r.ref.pieces]


class StoreVariableReader:
    """Progressive reader over one stored (possibly chunked) variable.

    Chunk states are independent (each chunk was refactored separately), so
    the variable-level bound is the max over chunk bounds and a tolerance
    request maps to the same tolerance per chunk."""

    # ``incremental=False`` wires the chunk readers to the from-scratch
    # full-decode oracle: EVERY reconstruction re-decodes every chunk with
    # no cross-chunk batching or caching.  It exists for bit-exactness
    # debugging against the engine, not for serving.
    def __init__(self, store: lo.DatasetStore, name: str,
                 backend: Optional[str] = None, incremental: bool = True,
                 depth: Optional[int] = None, mesh: shd.MeshLike = None,
                 degrade: bool = False,
                 shared: Optional[sv.ServingTier] = None, tenant: int = 0):
        var = store.variable(name)
        self.var = var
        self.name = name
        # replay the write-time plan recorded in the manifest (tuned decode
        # kernel tiling + overlap depth); absent on pre-autotune stores the
        # built-in defaults apply.  Explicit kwargs win over the plan, the
        # same resolution order as the write side.
        plan_cfg = (tn.RefactorConfig.from_json(var.plan)
                    if var.plan is not None else None)
        cfg = tn.as_config(plan_cfg, backend=backend, depth=depth)
        self.plan_config = cfg
        self.backend = cfg.backend
        self.incremental = incremental
        self.depth = max(int(cfg.depth), 1)  # overlap feeder look-ahead
        # chunk -> device placement: the manifest's recorded shard map (if
        # the variable was written sharded) taken modulo this mesh's size,
        # else round-robin; mesh=None keeps every engine uncommitted
        self.sharded = shd.ShardedReconstructEngine(mesh, shards=var.shards)
        self.degrade = degrade
        # shared=: the service's serving tier (plane cache + coalescing +
        # cross-session batched decode).  Scope keys by (variable, chunk):
        # every session of one service replays the same manifest plan, so
        # decoded plane groups are exchangeable across its sessions.
        self.chunk_readers = [
            ProgressiveReader(lo.chunk_refactored(var, ci),
                              source=StoreSegmentSource(store, name, ci),
                              incremental=incremental,
                              device=self.sharded.device_for(ci),
                              config=cfg, degrade=degrade,
                              shared=shared, shared_scope=(name, ci),
                              shared_tenant=tenant)
            for ci in range(len(var.chunks))]
        self.ref = _VarRef(var, self.chunk_readers)
        # assembled-variable cache, keyed on the fetch signature; per-chunk
        # reconstructions are cached inside each chunk reader's engine.  The
        # host copy is memoized separately so repeat requests at a met
        # tolerance return the identical ndarray object (no re-decode, no
        # re-transfer).
        self._recon: Optional[Tuple[tuple, jax.Array, float]] = None
        self._recon_np: Optional[Tuple[tuple, np.ndarray]] = None

    # -- QoI-loop surface ----------------------------------------------------
    @property
    def state(self):
        return [s for r in self.chunk_readers for s in r.state]

    @property
    def total_bytes_fetched(self) -> int:
        return sum(r.total_bytes_fetched for r in self.chunk_readers)

    def current_bound(self) -> float:
        return max((r.current_bound() for r in self.chunk_readers), default=0.0)

    def floor_bound(self) -> float:
        return max((r.floor_bound() for r in self.chunk_readers), default=0.0)

    def peek_best(self) -> Tuple[float, Optional[Tuple[int, int]]]:
        best_score, best = -1.0, None
        for ci, r in enumerate(self.chunk_readers):
            score, piece = r.peek_best()
            if piece is not None and score > best_score:
                best_score, best = score, (ci, piece)
        return best_score, best

    def fetch_one_more_group(self) -> int:
        _, best = self.peek_best()
        if best is None:
            return 0
        ci, piece = best
        r = self.chunk_readers[ci]
        target = [s.groups_fetched for s in r.state]
        target[piece] += 1
        return r._fetch_to(target)

    def decoded_plane_bytes(self) -> int:
        return sum(r.decoded_plane_bytes() for r in self.chunk_readers)

    def delta_decoded_bytes(self) -> int:
        return sum(r.delta_decoded_bytes() for r in self.chunk_readers)

    @property
    def degraded_count(self) -> int:
        """Plane groups dropped by the degrade policy across all chunks."""
        return sum(r.degraded_count for r in self.chunk_readers)

    @property
    def degraded(self) -> List[Tuple[int, int, int, str]]:
        """(chunk, piece, group, errtype) degradation events, all chunks."""
        return [(ci, p, g, e) for ci, r in enumerate(self.chunk_readers)
                for (p, g, e) in r.degraded]

    def reset_degraded(self) -> None:
        for r in self.chunk_readers:
            r.reset_degraded()

    # -- retrieval -----------------------------------------------------------
    def _assemble(self, outs: List[Tuple[jax.Array, float]]
                  ) -> Tuple[jax.Array, float]:
        if not outs:
            return jnp.zeros(self.var.shape, jnp.float32), 0.0
        parts = [o[0].reshape(-1) for o in outs]
        if self.sharded.mesh is not None and len(parts) > 1:
            # shards live on their owning devices; jnp.concatenate requires
            # colocated operands, so gather to the mesh's first device (the
            # read side's D2H-equivalent join — values are bit-unchanged)
            d0 = self.sharded.devices[0]
            parts = [jax.device_put(p, d0) for p in parts]
        flat = jnp.concatenate(parts)
        return flat.reshape(self.var.shape), max(o[1] for o in outs)

    # The assembled variable is cached on the fetch signature; chunk-level
    # reuse lives in each chunk reader's engine (clean engines return their
    # cached device array, partially-stale ones recompose only a suffix).
    # Returned arrays are shared — treat as read-only.
    def _signature(self) -> tuple:
        return tuple(s.groups_fetched
                     for r in self.chunk_readers for s in r.state)

    def reconstruct_device(self) -> Tuple[jax.Array, float]:
        sig = self._signature()
        if self._recon is not None and self._recon[0] == sig:
            return self._recon[1], self._recon[2]
        outs = reconstruct_many(self.chunk_readers, self.backend)
        x, bound = self._assemble(outs)
        self._recon = (sig, x, bound)
        return x, bound

    def reconstruct(self) -> Tuple[np.ndarray, float]:
        x_dev, bound = self.reconstruct_device()
        sig = self._recon[0]
        if self._recon_np is None or self._recon_np[0] != sig:
            self._recon_np = (sig, np.asarray(x_dev))
        return self._recon_np[1], bound

    def retrieve_device(self, tol: float, relative: bool = False
                        ) -> Tuple[jax.Array, float, int]:
        if relative:
            tol = tol * self.var.range
        fetched = _warm_and_fetch([(r, r.plan(tol)) for r in self.chunk_readers],
                                  depth=self.depth)
        x, bound = self.reconstruct_device()
        return x, bound, fetched

    def retrieve(self, tol: float, relative: bool = False
                 ) -> Tuple[np.ndarray, float, int]:
        _, bound, fetched = self.retrieve_device(tol, relative=relative)
        x, _ = self.reconstruct()  # memoized host copy of the same state
        return x, bound, fetched


def _warm_and_fetch(plans: List[Tuple[ProgressiveReader, List[int]]],
                    depth: int = 2) -> int:
    """Overlapped fetch of many chunk plans: backend I/O (cache warming) on
    the feeder thread, at most ``depth`` plans ahead of the lossless
    decompress running on the caller thread."""
    def warm(i: int):
        r, target = plans[i]
        wants = r.pending_deltas(target)
        if r.shared is not None:
            # serving tier: warming a byte range whose DECODED group is
            # already cached (or being decoded by another session) is pure
            # waste — and would break the one-backend-read-per-group
            # contract's accounting.  Empty pieces are never read at all.
            wants = [d for d in wants
                     if r.ref.pieces[d[0]].n > 0
                     and r.shared.should_warm(r.shared_scope + d)]
        if wants and hasattr(r.source, "warm"):
            with obs_trace.span("serve.warm", chunk=i, groups=len(wants)):
                r.source.warm(wants)
        return target

    def fetch(i: int, target) -> int:
        with obs_trace.span("serve.fetch", chunk=i):
            return plans[i][0]._fetch_to(target)

    return sum(pl.overlap_map(len(plans), warm, fetch, depth=depth))


# ---------------------------------------------------------------- sessions --

@dataclasses.dataclass
class SessionStats:
    """Per-session counters (thread-safe).  ``add`` applies a whole request's
    deltas atomically and ``snapshot`` reads under the same lock, so a
    snapshot taken mid-request never shows e.g. the request counted with its
    bytes missing (the historical torn-read race)."""
    requests: int = 0
    bytes_fetched: int = 0
    qoi_iterations: int = 0
    # plane groups served WITHOUT their data under the degrade policy —
    # every one of these widened some returned bound
    degraded_groups: int = 0

    def __post_init__(self):
        self._lock = threading.Lock()

    def add(self, **kw: int) -> None:
        with self._lock:
            for k, v in kw.items():
                setattr(self, k, getattr(self, k) + v)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {f.name: getattr(self, f.name)
                    for f in dataclasses.fields(self)}


class Session:
    """One client's progressive state over the store (thread-confined; take
    ``Session.lock`` when driving one session from several threads)."""

    def __init__(self, service: "RetrievalService", sid: int):
        self.service = service
        self.sid = sid
        self.lock = threading.Lock()
        self.stats = SessionStats()
        self._readers: Dict[str, StoreVariableReader] = {}

    def reader(self, var: str) -> StoreVariableReader:
        r = self._readers.get(var)
        if r is None:
            r = StoreVariableReader(self.service.store, var,
                                    self.service.backend,
                                    incremental=self.service.incremental,
                                    depth=self.service.depth,
                                    mesh=self.service.mesh,
                                    degrade=self.service.degrade,
                                    shared=self.service.tier,
                                    tenant=self.sid)
            self._readers[var] = r
        return r

    def _record_degraded(self, readers: Sequence[StoreVariableReader],
                         before: int) -> int:
        """Fold NEW degradation events since ``before`` into stats/metrics."""
        delta = sum(r.degraded_count for r in readers) - before
        if delta > 0:
            self.stats.add(degraded_groups=delta)
            obs_metrics.REGISTRY.get().inc("serve.degraded_groups", delta)
        return delta

    @property
    def bytes_fetched(self) -> int:
        return sum(r.total_bytes_fetched for r in self._readers.values())

    def retrieve(self, var: str, tol: float, relative: bool = False
                 ) -> Tuple[np.ndarray, float, int]:
        """Progressive max-norm retrieval; incremental across calls."""
        t0 = time.perf_counter()
        with obs_trace.span("serve.retrieve", session=self.sid, var=var):
            r = self.reader(var)
            deg_before = r.degraded_count
            x, bound, fetched = r.retrieve(tol, relative=relative)
        self.stats.add(requests=1, bytes_fetched=fetched)
        self._record_degraded([r], deg_before)
        m = obs_metrics.REGISTRY.get()
        m.inc("serve.requests")
        m.inc("serve.bytes_fetched", fetched)
        m.observe("serve.retrieve_s", time.perf_counter() - t0)
        return x, bound, fetched

    def retrieve_qoi(self, variables: Sequence[str], q: qq.QoI, tau: float,
                     method: str = "mape", **kw) -> qq.QoIRetrievalResult:
        """Guaranteed-QoI retrieval (Algorithm 3) over store-backed readers;
        session state persists, so tightening tau is incremental too."""
        readers = [self.reader(v) for v in variables]
        before = sum(r.total_bytes_fetched for r in readers)
        deg_before = sum(r.degraded_count for r in readers)
        res = qq.progressive_qoi_retrieve(readers, q, tau, method=method, **kw)
        self.stats.add(
            requests=1, qoi_iterations=res.iterations,
            bytes_fetched=sum(r.total_bytes_fetched
                              for r in readers) - before)
        self._record_degraded(readers, deg_before)
        return res


class RetrievalService:
    """Multiplexes concurrent progressive-retrieval sessions over one store."""

    def __init__(self, store: lo.DatasetStore, backend: Optional[str] = None,
                 incremental: bool = True, depth: Optional[int] = None,
                 mesh: shd.MeshLike = None, degrade: bool = False,
                 serving: bool = True,
                 plane_cache_bytes: Optional[int] = None,
                 coalesce_window_s: float = sv.DEFAULT_WINDOW_S):
        self.store = store
        # None lets each variable reader replay its manifest plan (tuned
        # decode knobs); an explicit value overrides the plan for every var
        self.backend = backend
        self.incremental = incremental
        self.depth = depth
        # degrade=True: unreachable plane groups widen the served bound
        # instead of failing the request (see docs/reliability.md)
        self.degrade = degrade
        # mesh-sharded serving: every session's variable readers place their
        # chunk engines across this mesh's devices (core.sharded)
        self.mesh = shd.resolve_mesh(mesh)
        # the serving tier (docs/serving.md): shared plane cache + request
        # coalescing + cross-session batched decode.  One tier per service —
        # its sessions share manifest plans and mesh placement, which is
        # what makes decoded plane groups exchangeable between them.
        # ``plane_cache_bytes=0`` keeps coalescing but disables retention;
        # ``serving=False`` turns the tier off entirely (fully private
        # per-session decode).  The oracle path (incremental=False) is
        # always private by construction.
        self.tier = (sv.ServingTier(
            cache_bytes=(sv.DEFAULT_PLANE_CACHE_BYTES
                         if plane_cache_bytes is None
                         else int(plane_cache_bytes)),
            window_s=coalesce_window_s)
            if serving and incremental else None)
        self._sessions: Dict[int, Session] = {}
        self._ids = itertools.count(1)
        self._lock = threading.Lock()

    # -- session management --------------------------------------------------
    def open_session(self) -> Session:
        with self._lock:
            sid = next(self._ids)
            s = Session(self, sid)
            self._sessions[sid] = s
            return s

    def close_session(self, session: Session) -> None:
        with self._lock:
            self._sessions.pop(session.sid, None)

    @property
    def sessions(self) -> List[Session]:
        with self._lock:
            return list(self._sessions.values())

    # -- batched serving -----------------------------------------------------
    def retrieve_many(self, requests: Sequence[Tuple[Session, str, float]]
                      ) -> List[Tuple[np.ndarray, float, int]]:
        """Serve several (session, var, tol) requests in one batch.

        All requests' delta ranges are fetched through one overlapped pass,
        then the staged (still-undecoded) plane groups of every distinct
        reader are delta-decoded in one ``reconstruct.batch_apply_pending``
        pass — same-shaped groups across sessions share kernel launches, and
        state decoded for earlier requests is never re-decoded.  Duplicate
        (session, var) pairs in one batch share state: all get the
        (tightest) result, the fetched-byte delta is attributed to the first
        occurrence."""
        uniq: Dict[int, dict] = {}  # id(reader) -> accounting entry
        req_entries: List[Tuple[dict, bool]] = []
        # one plan per distinct chunk reader (elementwise max over duplicate
        # requests), so the overlapped fetch never touches a reader twice
        plan_map: Dict[int, Tuple[ProgressiveReader, List[int]]] = {}
        for session, var, tol in requests:
            vr = session.reader(var)
            ent = uniq.get(id(vr))
            first = ent is None
            if first:
                ent = {"session": session, "vr": vr,
                       "before": vr.total_bytes_fetched,
                       "deg_before": vr.degraded_count}
                uniq[id(vr)] = ent
            req_entries.append((ent, first))
            for r in vr.chunk_readers:
                target = r.plan(tol)
                prev = plan_map.get(id(r))
                if prev is not None:
                    target = [max(a, b) for a, b in zip(prev[1], target)]
                plan_map[id(r)] = (r, target)
        # service-level depth override wins; else the deepest involved
        # reader's (plan-replayed) look-ahead drives the batch fetch
        depth = (max((ent["vr"].depth for ent in uniq.values()),
                     default=tn.DEFAULT_CONFIG.depth)
                 if self.depth is None else max(int(self.depth), 1))
        t0 = time.perf_counter()
        with obs_trace.span("serve.retrieve_many", requests=len(requests),
                            readers=len(uniq)):
            _warm_and_fetch(list(plan_map.values()), depth=depth)
            # one cross-session batched delta decode over every distinct
            # reader's staged plane groups (per mesh device when sharded)
            with obs_trace.span("serve.decode", readers=len(uniq)):
                shd.ShardedReconstructEngine.drain(
                    [cr.engine for ent in uniq.values()
                     for cr in ent["vr"].chunk_readers if cr.incremental])
            results = []
            for ent, first in req_entries:
                vr = ent["vr"]
                x, bound = vr.reconstruct()  # drained: delta recompose only
                fetched = (vr.total_bytes_fetched - ent["before"]) \
                    if first else 0
                ent["session"].stats.add(requests=1, bytes_fetched=fetched)
                if first:
                    ent["session"]._record_degraded([vr], ent["deg_before"])
                results.append((x, bound, fetched))
        m = obs_metrics.REGISTRY.get()
        m.inc("serve.requests", len(requests))
        m.inc("serve.bytes_fetched",
              sum(ent["vr"].total_bytes_fetched - ent["before"]
                  for ent in uniq.values()))
        m.observe("serve.retrieve_s", time.perf_counter() - t0)
        return results

    # -- accounting ----------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        backend_stats = self.store.stats()
        with self._lock:
            per_session = {s.sid: s.stats.snapshot()
                           for s in self._sessions.values()}
        return {
            "store_bytes": self.store.stored_bytes,
            "backend": backend_stats.snapshot() if backend_stats else None,
            "serving": self.tier.snapshot() if self.tier else None,
            "sessions": per_session,
        }
