"""On-disk dataset layout: one JSON manifest + per-variable segment files.

Directory structure::

    <root>/
      manifest.json            # everything but payload bytes (see Manifest)
      segments/<var>.seg       # concatenated ll.Segment.to_bytes() blobs

The manifest records, per variable, per chunk, per piece: the error-model
parameters (element count, alignment exponent, recomposition weight) and the
byte range + lossless method of every merged plane group (and of the sign
segment).  A reader therefore plans greedy rate allocation and issues exact
byte-range reads without ever deserializing segments it does not need —
the unit of I/O is one (chunk, piece, group) range, the same granularity as
MDR's incremental retrieval.

``chunk_refactored`` materializes a payload-free ``core.refactor.Refactored``
(stub segments carry ``meta["stored_bytes"]``) that plugs straight into
``core.retrieve.ProgressiveReader`` with a store-backed ``SegmentSource``.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

from repro.core import lossless as ll
from repro.core import refactor as rf
from repro.store import backend as bk
from repro.store import reliability as rl

MANIFEST_NAME = "manifest.json"
SEGMENT_DIR = "segments"
FORMAT = "repro.store/v1"


@dataclasses.dataclass(frozen=True)
class GroupRef:
    """Byte-range address of one stored segment.

    ``crc`` is the CRC-32 of the stored blob (``reliability.checksum``),
    recorded at write time and verified on every backend read — so a flipped
    byte anywhere in the range surfaces as a typed ``CorruptSegmentError``
    at the exact (chunk, piece, group) that rotted, instead of as a decode
    crash or (for dc/store-raw payloads, which have no framing of their own)
    silently wrong data.  Compatibility mirrors ``shards``/``plan``: absent
    (None, pre-checksum stores) means unchecked; serialized as an optional
    4th list element that pre-checksum readers never look at."""
    offset: int
    size: int
    method: str
    crc: Optional[int] = None

    def to_json(self) -> List:
        if self.crc is None:
            return [self.offset, self.size, self.method]
        return [self.offset, self.size, self.method, self.crc]

    @staticmethod
    def from_json(j: List) -> "GroupRef":
        crc = int(j[3]) if len(j) > 3 and j[3] is not None else None
        return GroupRef(int(j[0]), int(j[1]), str(j[2]), crc)


@dataclasses.dataclass
class PieceEntry:
    n: int                       # elements in the piece
    exponent: int                # alignment exponent (error model)
    weight: float                # recomposition weight (error model)
    n_words: int                 # uint32 words per plane
    group_planes: List[int]      # planes per merged group, MSB first
    sign: GroupRef
    groups: List[GroupRef]

    def to_json(self) -> Dict:
        return {"n": self.n, "exponent": self.exponent, "weight": self.weight,
                "n_words": self.n_words, "group_planes": self.group_planes,
                "sign": self.sign.to_json(),
                "groups": [g.to_json() for g in self.groups]}

    @staticmethod
    def from_json(j: Dict) -> "PieceEntry":
        return PieceEntry(
            n=int(j["n"]), exponent=int(j["exponent"]),
            weight=float(j["weight"]), n_words=int(j["n_words"]),
            group_planes=[int(g) for g in j["group_planes"]],
            sign=GroupRef.from_json(j["sign"]),
            groups=[GroupRef.from_json(g) for g in j["groups"]])


@dataclasses.dataclass
class ChunkEntry:
    n_elements: int
    amax: float                  # chunk max |x| (error model)
    range: float                 # chunk value range (relative tolerances)
    pieces: List[PieceEntry]

    @property
    def stored_bytes(self) -> int:
        return sum(p.sign.size + sum(g.size for g in p.groups)
                   for p in self.pieces)

    def to_json(self) -> Dict:
        return {"n_elements": self.n_elements, "amax": self.amax,
                "range": self.range,
                "pieces": [p.to_json() for p in self.pieces]}

    @staticmethod
    def from_json(j: Dict) -> "ChunkEntry":
        return ChunkEntry(
            n_elements=int(j["n_elements"]), amax=float(j["amax"]),
            range=float(j["range"]),
            pieces=[PieceEntry.from_json(p) for p in j["pieces"]])


@dataclasses.dataclass
class VariableEntry:
    name: str
    shape: Tuple[int, ...]
    levels: int
    design: str
    mag_bits: int
    group_size: int
    chunk_elems: int
    segment_file: str            # key relative to the store root
    amax: float                  # global max |x| over the variable
    range: float                 # global max(x) - min(x)
    chunks: List[ChunkEntry]
    # chunk -> shard ordinal of the mesh the variable was written on
    # (core.sharded round-robin).  Purely a placement HINT for readers —
    # payload bytes are placement-independent (single-device-oracle
    # guarantee), and absent (None) means single-device.  Readers take it
    # modulo their own mesh size, so N-device stores read fine on M devices.
    shards: Optional[List[int]] = None
    # the effective RefactorConfig the variable was WRITTEN with
    # (repro.tune.config.RefactorConfig.to_json()): readers replay the tuned
    # plan — decode kernel tiling, overlap depth — instead of re-guessing
    # defaults.  Absent (None) on stores written before autotuning existed;
    # the authoritative quality fields (design/mag_bits/group_size) above
    # stay where they always were, the plan only adds the perf knobs.
    plan: Optional[Dict] = None

    @property
    def n_elements(self) -> int:
        n = 1
        for s in self.shape:
            n *= int(s)
        return n

    @property
    def stored_bytes(self) -> int:
        return sum(c.stored_bytes for c in self.chunks)

    def to_json(self) -> Dict:
        out = {"name": self.name, "shape": list(self.shape),
               "levels": self.levels, "design": self.design,
               "mag_bits": self.mag_bits, "group_size": self.group_size,
               "chunk_elems": self.chunk_elems,
               "segment_file": self.segment_file,
               "amax": self.amax, "range": self.range,
               "chunks": [c.to_json() for c in self.chunks]}
        if self.shards is not None:
            out["shards"] = list(self.shards)
        if self.plan is not None:
            out["plan"] = dict(self.plan)
        return out

    @staticmethod
    def from_json(j: Dict) -> "VariableEntry":
        # unknown keys in j are ignored (forward compatibility: stores
        # written by newer code must stay readable), and optional keys
        # (shards, plan) may be absent (backward compatibility: pre-shards /
        # pre-plan stores load and serve) — tested in tests/test_store.py
        shards = j.get("shards")
        plan = j.get("plan")
        return VariableEntry(
            name=str(j["name"]), shape=tuple(int(s) for s in j["shape"]),
            levels=int(j["levels"]), design=str(j["design"]),
            mag_bits=int(j["mag_bits"]), group_size=int(j["group_size"]),
            chunk_elems=int(j["chunk_elems"]),
            segment_file=str(j["segment_file"]),
            amax=float(j["amax"]), range=float(j["range"]),
            chunks=[ChunkEntry.from_json(c) for c in j["chunks"]],
            shards=None if shards is None else [int(s) for s in shards],
            plan=None if plan is None else dict(plan))


@dataclasses.dataclass
class Manifest:
    variables: Dict[str, VariableEntry] = dataclasses.field(default_factory=dict)

    @property
    def stored_bytes(self) -> int:
        return sum(v.stored_bytes for v in self.variables.values())

    def to_json(self, integrity: bool = True) -> Dict:
        """``integrity=True`` (what the writer commits) adds a ``"crc32"``
        key over the canonical serialization of ``variables`` — a flipped
        byte anywhere in the manifest body then fails ``from_json`` with a
        typed error instead of silently rewriting offsets, sizes, or error-
        model metadata.  Old readers ignore the unknown key (forward
        compatible); manifests without it load unchecked (backward
        compatible), same rules as ``shards``/``plan``."""
        vars_json = {k: v.to_json() for k, v in self.variables.items()}
        out = {"format": FORMAT, "variables": vars_json}
        if integrity:
            out["crc32"] = rl.manifest_body_checksum(vars_json)
        return out

    @staticmethod
    def from_json(j: Dict) -> "Manifest":
        if j.get("format") != FORMAT:
            raise ValueError(f"unsupported store format: {j.get('format')!r}")
        vars_json = j.get("variables", {})
        if "crc32" in j:
            got = rl.manifest_body_checksum(vars_json)
            if got != (int(j["crc32"]) & 0xFFFFFFFF):
                raise rl.CorruptSegmentError(
                    f"manifest integrity check failed: stored "
                    f"crc32=0x{int(j['crc32']) & 0xFFFFFFFF:08x}, computed "
                    f"0x{got:08x} over the variables body")
        return Manifest({k: VariableEntry.from_json(v)
                         for k, v in vars_json.items()})


# --------------------------------------------------------------- chunk meta --

def chunk_entry_from_refactored(refd: rf.Refactored, write,
                                checksums: bool = True) -> ChunkEntry:
    """Serialize one chunk's segments through ``write(blob) -> offset`` (an
    appending writer returning the blob's start offset) and build its entry.

    Uses the canonical ``rf.iter_segments`` stream order, so offsets address
    the same bytes ``refactored_to_bytes`` would have produced segment-wise.
    ``checksums=True`` records each blob's CRC-32 on its ``GroupRef`` so
    readers verify every byte-range read (see ``repro.store.reliability``).
    """
    meta = rf.refactored_meta(refd)
    refs: List[List[Optional[GroupRef]]] = [
        [None] * (1 + len(p.groups)) for p in refd.pieces]
    for pi, kind, gi, seg in rf.iter_segments(refd):
        blob = seg.to_bytes()
        off = write(blob)
        slot = 0 if kind == "sign" else 1 + gi
        refs[pi][slot] = GroupRef(off, len(blob), seg.method,
                                  rl.checksum(blob) if checksums else None)
    pieces = []
    for pi, pm in enumerate(meta["pieces"]):
        pieces.append(PieceEntry(
            n=pm["n"], exponent=pm["exponent"], weight=pm["weight"],
            n_words=pm["n_words"], group_planes=pm["group_planes"],
            sign=refs[pi][0], groups=refs[pi][1:]))
    return ChunkEntry(n_elements=refd.n_elements, amax=refd.data_amax,
                      range=refd.data_range, pieces=pieces)


def _stub(ref_: GroupRef, n_planes: int, n_words: int) -> ll.Segment:
    return ll.Segment(ref_.method, 0, payload={},
                      meta={"stored_bytes": ref_.size, "n_planes": n_planes,
                            "n_words": n_words})


def chunk_refactored(var: VariableEntry, ci: int) -> rf.Refactored:
    """Payload-free ``Refactored`` for chunk ``ci`` (planner-ready stubs)."""
    ch = var.chunks[ci]
    meta = {
        "name": f"{var.name}.{ci}", "shape": [ch.n_elements],
        "levels": var.levels, "design": var.design,
        "mag_bits": var.mag_bits, "group_size": var.group_size,
        "amax": ch.amax, "range": ch.range,
        "pieces": [p.to_json() for p in ch.pieces],
    }

    def segments(pi: int, kind: str, gi: int) -> ll.Segment:
        p = ch.pieces[pi]
        if kind == "sign":
            return _stub(p.sign, 1, p.n_words)
        return _stub(p.groups[gi], p.group_planes[gi], p.n_words)

    return rf.refactored_from_meta(meta, segments)


# -------------------------------------------------------------------- store --

class DatasetStore:
    """Read-side handle on a stored dataset: manifest + byte-range reads.

    ``backend`` is any ``repro.store.backend.FetchBackend``; by default a
    ``LocalFileBackend`` rooted at the store directory wrapped in a
    ``CachingBackend`` (LRU segment cache + async prefetch queue).  When the
    ``REPRO_CHAOS`` env var is set (the CI chaos job), the default file
    backend is additionally wrapped in a seeded ``FaultInjectionBackend`` +
    ``RetryingBackend`` — so ordinary test suites exercise the whole read
    stack under injected faults with zero test changes.

    ``verify=True`` (default) checks the recorded CRC-32 of every segment
    read (``GroupRef.crc``); pre-checksum stores carry no CRCs and read
    unchecked, exactly as before."""

    def __init__(self, manifest: Manifest, backend: bk.FetchBackend,
                 verify: bool = True):
        self.manifest = manifest
        self.backend = backend
        self.verify = verify

    @classmethod
    def open(cls, root: str, backend: Optional[bk.FetchBackend] = None,
             cache_bytes: int = 64 << 20,
             prefetch_workers: int = 2, verify: bool = True) -> "DatasetStore":
        if backend is None:
            backend = bk.CachingBackend(
                rl.chaos_from_env(bk.LocalFileBackend(root)),
                capacity_bytes=cache_bytes,
                workers=prefetch_workers)
        raw = backend.read(MANIFEST_NAME, 0, backend.size(MANIFEST_NAME))
        return cls(Manifest.from_json(json.loads(raw.decode())), backend,
                   verify=verify)

    @property
    def variables(self) -> List[str]:
        return list(self.manifest.variables)

    def variable(self, name: str) -> VariableEntry:
        return self.manifest.variables[name]

    @property
    def stored_bytes(self) -> int:
        return self.manifest.stored_bytes

    # -- raw segment access -------------------------------------------------
    def read_segment(self, var: str, ref_: GroupRef) -> ll.Segment:
        v = self.manifest.variables[var]
        blob = self.backend.read(v.segment_file, ref_.offset, ref_.size)
        if len(blob) != ref_.size:
            raise rl.TruncatedReadError(
                f"backend returned {len(blob)} bytes for "
                f"{v.segment_file}@{ref_.offset}+{ref_.size}")
        if self.verify and ref_.crc is not None:
            rl.verify_checksum(
                blob, ref_.crc,
                context=f"{v.segment_file}@{ref_.offset}+{ref_.size}")
        return ll.Segment.from_bytes(blob)

    def prefetch_segment(self, var: str, ref_: GroupRef) -> None:
        v = self.manifest.variables[var]
        self.backend.prefetch(v.segment_file, ref_.offset, ref_.size)

    def stats(self) -> Optional[bk.BackendStats]:
        return getattr(self.backend, "stats", None)

    def close(self) -> None:
        self.backend.close()

    def __enter__(self) -> "DatasetStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def segment_key(var: str, generation: Optional[str] = None) -> str:
    """Backend key (store-root-relative path) of a variable's segment file.

    Writers pass a per-write ``generation`` token so rewriting a variable in
    an existing store never touches bytes an older manifest addresses: the
    old manifest keeps pointing at the old file until the new manifest is
    atomically renamed into place (crash -> old store still consistent;
    leftover orphan generations are harmless)."""
    gen = f"-{generation}" if generation else ""
    return f"{SEGMENT_DIR}/{var}{gen}.seg"


def segment_path(root: str, key_or_var: str) -> str:
    """Absolute path for a backend key (or bare variable name)."""
    if "/" not in key_or_var:
        key_or_var = segment_key(key_or_var)
    return os.path.join(root, *key_or_var.split("/"))
