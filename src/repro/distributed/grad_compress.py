"""Progressive gradient compression (the paper's encoder on the wire).

Two pieces:

1. ``compressed_psum``: a drop-in for ``jax.lax.psum`` over a mesh axis that
   transmits only the top-P bitplane groups:
       reduce_scatter(fp32) -> exponent-align -> bitplane encode ->
       all_gather(packed planes, P/31 of the bytes) -> decode locally
   The all-gather payload shrinks to ~P/31 of the raw gradient — directly
   visible in the dry-run HLO as a smaller collective term.  Built on
   shard_map; returns (result, local truncation residual) so callers can do
   error feedback.

2. ``ef_quantize``: error-feedback bitplane truncation for the optimizer
   path (grads quantized to P planes, the truncation error is carried to the
   next step) — the convergence-preserving half, testable on 1 device.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import axis_size as _axis_size
from repro.distributed.sharding import shard_map as _shard_map

from repro.kernels import ref as kref

MAG_BITS = 23  # exact fp32 quantization bound (see core/align.py)


def _encode_planes(x: jax.Array, planes: int) -> Tuple[jax.Array, jax.Array]:
    """fp32 vector -> (packed top-`planes` magnitude planes + sign plane, e)."""
    amax = jnp.max(jnp.abs(x))
    _, e = jnp.frexp(amax)
    e = jnp.where(amax > 0, e, 0).astype(jnp.int32)
    scale = jnp.exp2((MAG_BITS - e).astype(jnp.float32))
    q = jnp.round(x * scale)
    sign = (q < 0).astype(jnp.uint32)
    # keep only the top `planes` magnitude bits before encoding (3.75x less
    # transpose work than encoding all 30 and slicing)
    mag_top = (jnp.abs(q).astype(jnp.uint32)) >> jnp.uint32(MAG_BITS - planes)
    mag_planes = kref.encode(mag_top, planes, "register_block")
    sign_plane = kref.encode(sign, 1, "register_block")
    packed = jnp.concatenate([sign_plane, mag_planes], axis=0)
    return packed, e


def _decode_planes(packed: jax.Array, e: jax.Array, n: int, planes: int
                   ) -> jax.Array:
    sign = kref.decode(packed[:1], 1, n, "register_block")
    mag = kref.decode(packed[1:], planes, n, "register_block")
    tail = MAG_BITS - planes
    mag = mag << jnp.uint32(tail)
    if tail > 0:
        mag = mag + jnp.uint32(1 << (tail - 1))  # midpoint decode
    scale = jnp.exp2((MAG_BITS - e).astype(jnp.float32))
    val = mag.astype(jnp.float32) / scale
    return jnp.where(sign > 0, -val, val)


def ef_quantize(x: jax.Array, residual: jax.Array, planes: int
                ) -> Tuple[jax.Array, jax.Array]:
    """Bitplane-truncate (x+residual) to `planes`; return (q, new_residual)."""
    flat = (x + residual).astype(jnp.float32).reshape(-1)
    packed, e = _encode_planes(flat, planes)
    q = _decode_planes(packed, e, flat.shape[0], planes).reshape(x.shape)
    return q, (x + residual - q)


def compressed_psum(x: jax.Array, axis_name: str, planes: int = 8
                    ) -> Tuple[jax.Array, jax.Array]:
    """Inside shard_map: mean-reduce `x` over `axis_name` transmitting only
    `planes` magnitude planes in the gather phase.

    Returns (reduced, residual): `residual` is THIS device's truncation error
    on its reduce-scatter shard (for error feedback)."""
    n_dev = _axis_size(axis_name)
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % (n_dev * 4096)
    flat = jnp.pad(flat, (0, pad))
    # phase 1: reduce-scatter raw fp32 (wire = S*(n-1)/n, unavoidable for sum)
    shard = jax.lax.psum_scatter(flat.reshape(n_dev, -1), axis_name,
                                 scatter_dimension=0, tiled=False) / n_dev
    n_local = shard.shape[0]
    # phase 2: encode shard, all-gather only the packed planes
    packed, e = _encode_planes(shard, planes)
    e_all = jax.lax.all_gather(e, axis_name)                  # scalar each
    packed_all = jax.lax.all_gather(packed, axis_name)        # (n, P+1, W)
    decoded = jax.vmap(lambda pk, ee: _decode_planes(pk, ee, n_local, planes)
                       )(packed_all, e_all)
    residual = shard - _decode_planes(packed, e, n_local, planes)
    out = decoded.reshape(-1)[:x.size].reshape(x.shape)
    return out, residual


def make_compressed_allreduce(mesh, axis_name: str, planes: int = 8):
    """jit-ready f(x) -> (mean_over_axis, residual_shard) via shard_map."""
    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=P(axis_name), out_specs=(P(axis_name), P(axis_name)),
    )
    def f(x_shard):
        out, res = compressed_psum(x_shard, axis_name, planes)
        return out, res
    return f
