"""Mesh context + sharding rules.

Physical mesh axes: ``('data', 'model')`` single-pod, ``('pod', 'data',
'model')`` multi-pod.  Data parallelism (and ZeRO-3 parameter sharding)
spans ('pod','data'); tensor/expert parallelism spans 'model'.

Model code calls :func:`acts` / :func:`constraint` with *logical* specs and
the helpers translate to whatever axes the current mesh actually has, so the
same model runs on a 1x1 smoke-test mesh, a 16x16 pod, or a 2x16x16 slice.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def set_current_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


def get_current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = get_current_mesh()
    set_current_mesh(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        set_current_mesh(prev)


def _filter_axes(axes: Union[None, str, Sequence[str]], mesh: Mesh):
    """Keep only axes present in the mesh; collapse empty tuples to None."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    kept = tuple(a for a in axes if a in mesh.axis_names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def spec(*dims) -> P:
    """PartitionSpec from logical per-dim axis requests, filtered by mesh.

    Each dim is None, an axis name, or a tuple of axis names.  'dp' expands
    to ('pod','data').  Without a current mesh, returns P() placeholders
    (constraints become no-ops)."""
    mesh = get_current_mesh()
    out = []
    for d in dims:
        if d == "dp":
            d = ("pod", "data")
        if mesh is None:
            out.append(None)
        else:
            out.append(_filter_axes(d, mesh))
    return P(*out)


def constraint(x: jax.Array, *dims) -> jax.Array:
    """with_sharding_constraint if a mesh is active, else identity."""
    mesh = get_current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec(*dims)))


def named(pspec: P) -> Optional[NamedSharding]:
    mesh = get_current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, pspec)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: Optional[bool] = None):
    """jax.shard_map across jax versions.

    jax >= 0.6 exposes ``jax.shard_map(..., check_vma=)``; 0.4.x has it at
    ``jax.experimental.shard_map.shard_map(..., check_rep=)``.  All in-repo
    call sites go through this wrapper so version skew is handled once."""
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if hasattr(jax, "shard_map"):
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm
    if check_vma is not None:
        kwargs["check_rep"] = check_vma
    return _sm(f, **kwargs)


def axis_size(axis_name: str) -> int:
    """Static mesh-axis size inside shard_map (jax.lax.axis_size is >= 0.5)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return int(jax.lax.psum(1, axis_name))


def dp_size(mesh: Optional[Mesh] = None) -> int:
    mesh = mesh or get_current_mesh()
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def tp_size(mesh: Optional[Mesh] = None) -> int:
    mesh = mesh or get_current_mesh()
    return mesh.shape.get("model", 1)
