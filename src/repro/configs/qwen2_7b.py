"""qwen2-7b — GQA kv=4 with QKV bias [arXiv:2407.10671; hf].

28 query heads do not divide the 16-way model axis; the sharding policy keeps
attention head-local and uses the model axis for extra data/sequence
parallelism (see launch/policy.py)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True, rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="qwen2-smoke", family="dense",
    n_layers=2, d_model=112, n_heads=7, n_kv_heads=1,
    d_ff=224, vocab_size=512, qkv_bias=True, compute_dtype="float32",
)
