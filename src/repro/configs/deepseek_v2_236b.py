"""deepseek-v2-236b — MLA (kv_lora=512), MoE 2 shared + 160 routed top-6
[arXiv:2405.04434; hf]."""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288,  # dense first layer FFN
    vocab_size=102400,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
                  first_dense=1),
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="dsv2-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                  qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
    # capacity_factor=4.0 makes cap == T at smoke sizes, so no token is ever
    # capacity-dropped (each token contributes <= 1 assignment per expert) and
    # prefill+decode is numerically consistent with the full forward.
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=1,
                  first_dense=1, capacity_factor=4.0),
    compute_dtype="float32",
)
