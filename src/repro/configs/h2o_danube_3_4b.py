"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab_size=32000,
    attn_window=4096,  # mistral-style SWA -> long_500k decode is O(window)
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="danube3-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab_size=512, attn_window=64, compute_dtype="float32",
)
