"""rwkv6-3b — Finch, data-dependent decay, attention-free [arXiv:2404.05892; hf]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab_size=65536,
    ssm=SSMConfig(kind="rwkv6"),
    norm="layernorm",
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
    d_ff=256, vocab_size=512,
    ssm=SSMConfig(kind="rwkv6"),
    norm="layernorm", compute_dtype="float32",
)
