"""hubert-xlarge — encoder-only audio transformer; the conv feature frontend
is a stub (input_specs supplies precomputed frame embeddings)
[arXiv:2106.07447; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab_size=504,
    norm="layernorm", encoder_only=True, external_embed=True,
)

SMOKE = ModelConfig(
    name="hubert-smoke", family="audio",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=64,
    norm="layernorm", encoder_only=True, external_embed=True,
    compute_dtype="float32",
)
