from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, MLAConfig, SSMConfig, ShapeConfig, RunConfig,
    SHAPES, get_config, list_archs, smoke_config, input_specs, ARCH_REGISTRY,
)
