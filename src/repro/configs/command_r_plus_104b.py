"""command-r-plus-104b — parallel attn+FFN blocks, LayerNorm, no bias, tied
embeddings [hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=33792, vocab_size=256000,
    norm="layernorm", parallel_block=True, tie_embeddings=True,
    rope_theta=75000000.0, param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="command-r-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab_size=512,
    norm="layernorm", parallel_block=True, tie_embeddings=True,
    compute_dtype="float32",
)
