"""hpmdr-field — the paper's own workload: refactor/retrieve scientific
fields.  Not an LM; used by benchmarks and the quickstart example.  The
"config" records the dataset proxies (paper Table 1)."""
from repro.configs.base import ModelConfig

# placeholder ModelConfig so the registry stays uniform; the real knobs live
# in repro.data.fields.DATASETS and core.lossless.HybridConfig.
CONFIG = ModelConfig(
    name="hpmdr-field", family="field",
    n_layers=0, d_model=0, n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=0,
)
SMOKE = CONFIG
