"""deepseek-v3-671b — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437; hf]."""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432,  # dense layers' FFN
    vocab_size=129280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                  first_dense=3),
    mtp_depth=1,
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="dsv3-smoke", family="moe",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                  qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=1,
                  first_dense=1, capacity_factor=4.0),  # drop-free at smoke T
    mtp_depth=1,
    compute_dtype="float32",
)
