"""jamba-v0.1-52b — Mamba+attention 1:7 interleave, MoE 16e top-2 every other
layer [arXiv:2403.19887; hf].

Period-8 block: attention at offset 4 (1:7 ratio), MoE on odd layers."""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    ssm=SSMConfig(kind="mamba", d_state=16, expand=2, dt_rank=256,
                  conv_width=4, attn_period=8, attn_offset=4),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336, layer_period=2),
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=256,
    ssm=SSMConfig(kind="mamba", d_state=8, expand=2, dt_rank=8,
                  conv_width=4, attn_period=8, attn_offset=4),
    moe=MoEConfig(n_experts=4, top_k=2, d_expert=128, layer_period=2,
                  capacity_factor=2.0),  # cap == T at smoke T (k/E = 1/2)
    compute_dtype="float32",
)
