"""llama-3.2-vision-90b — text backbone with gated cross-attention image
layers every 5th layer; vision frontend is a stub (input_specs supplies
pre-projected patch embeddings) [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256,
    cross_attn_period=5, n_vision_tokens=1601,
    rope_theta=500000.0, param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="llama-vision-smoke", family="vlm",
    n_layers=5, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab_size=512,
    cross_attn_period=5, n_vision_tokens=17,
    compute_dtype="float32",
)
