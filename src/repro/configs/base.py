"""Config system: model/shape/run configs + the arch registry.

Every assigned architecture registers a full-size ``ModelConfig`` (exact
public-literature dimensions) plus a ``smoke_config`` reduction used by CPU
tests.  ``input_specs`` builds ShapeDtypeStruct stand-ins for every model
input of a given (arch x shape) cell — no device allocation, dry-run safe.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    n_shared: int = 0             # shared (always-on) experts
    first_dense: int = 0          # leading dense layers (deepseek)
    layer_period: int = 1         # MoE every k-th layer (jamba: 2)
    capacity_factor: float = 1.0
    aux_loss_weight: float = 0.001
    # EP dispatch: 'gspmd' lets the partitioner handle the capacity-buffer
    # scatter (baseline; materializes the buffer via all-reduce); 'shard_map'
    # builds each model-shard's local expert buffer manually (beyond-paper
    # §Perf optimization; no dispatch collective, combine = one psum).
    dispatch: str = "gspmd"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str                     # 'rwkv6' | 'mamba'
    d_state: int = 16             # mamba state / rwkv head dim
    expand: int = 2               # mamba inner expansion
    dt_rank: int = 0              # mamba delta rank (0 -> d_model//16)
    conv_width: int = 4
    attn_period: int = 0          # jamba: attention layer every k layers
    attn_offset: int = 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0               # 0 -> d_model // n_heads
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    qkv_bias: bool = False
    attn_window: int = 0          # 0 = full attention; >0 = SWA
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    parallel_block: bool = False  # cohere: attn and mlp in parallel
    encoder_only: bool = False    # hubert: bidirectional, no decode
    external_embed: bool = False  # audio/vlm: frontend supplies embeddings
    cross_attn_period: int = 0    # vlm: cross-attn every k-th layer
    n_vision_tokens: int = 0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    mtp_depth: int = 0            # deepseek-v3 multi-token prediction heads
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # decode-time runtime knobs (set by launch/policy per cell)
    seq_shard_decode: bool = False
    decode_batch_axes: Tuple[str, ...] = ("pod", "data")
    # HP-MDR on the KV cache: store K/V as int8 fixed point aligned at a
    # static exponent (the paper's alignment trick on serving state) ->
    # halves the decode memory term vs bf16.  0 = off; else the alignment
    # scale (values clipped to [-scale, scale]).
    kv_cache_int8_scale: float = 0.0

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Total parameters (analytic)."""
        from repro.models.model import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params
        return count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Per-(arch x shape) execution knobs."""
    microbatch: int = 0           # 0 -> auto (per-device batch 1)
    opt_state_dtype: str = "float32"
    remat_policy: str = "full"    # full | dots | none
    grad_compress_planes: int = 0 # 0 = off; else top-P plane-groups
    seq_shard_decode: bool = False


ARCH_REGISTRY: Dict[str, str] = {
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube_3_4b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "llama-3.2-vision-90b": "repro.configs.llama32_vision_90b",
    "hpmdr-field": "repro.configs.hpmdr_field",  # the paper's own workload
}


def list_archs() -> List[str]:
    return [a for a in ARCH_REGISTRY if a != "hpmdr-field"]


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(ARCH_REGISTRY[arch])
    return mod.CONFIG


def smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(ARCH_REGISTRY[arch])
    return mod.SMOKE


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Skip rules from DESIGN.md §7."""
    if cfg.encoder_only and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k":
        subquadratic = (cfg.ssm is not None) or cfg.attn_window > 0
        if not subquadratic:
            return False, "pure full-attention arch skips long_500k"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                dp: int = 1) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every input of the lowered step."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        if cfg.external_embed:
            specs["embeds"] = sds((b, s, cfg.d_model), jnp.bfloat16)
            specs["labels"] = sds((b, s), jnp.int32)
        else:
            specs["tokens"] = sds((b, s), jnp.int32)
            specs["labels"] = sds((b, s), jnp.int32)
        if cfg.cross_attn_period:
            specs["vision_states"] = sds((b, cfg.n_vision_tokens, cfg.d_model),
                                         jnp.bfloat16)
    elif shape.kind == "prefill":
        if cfg.external_embed:
            specs["embeds"] = sds((b, s, cfg.d_model), jnp.bfloat16)
        else:
            specs["tokens"] = sds((b, s), jnp.int32)
        if cfg.cross_attn_period:
            specs["vision_states"] = sds((b, cfg.n_vision_tokens, cfg.d_model),
                                         jnp.bfloat16)
    else:  # decode: one new token against a seq_len-deep cache
        specs["tokens"] = sds((b, 1), jnp.int32)
        if cfg.cross_attn_period:
            specs["vision_states"] = sds((b, cfg.n_vision_tokens, cfg.d_model),
                                         jnp.bfloat16)
    return specs
