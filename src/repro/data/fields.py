"""Synthetic scientific-field generator (NYX / JHTDB / Miranda proxies).

Real datasets are not available offline; benchmarks use spectral Gaussian
random fields with a tunable power-spectrum slope.  Steeper slopes give
smoother, more compressible fields (Miranda-like); shallower slopes approach
white noise (hard to compress).  The DC mode is zeroed and the spectrum uses
Hermitian-symmetric synthesis (irfftn), so fields are real with ~zero mean.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


def gaussian_field(shape: Sequence[int], slope: float = -2.0, seed: int = 0,
                   dtype=np.float32) -> np.ndarray:
    """Real Gaussian random field with isotropic power spectrum ~ k^slope."""
    shape = tuple(shape)
    rng = np.random.default_rng(seed)
    # rfftn frequency grid
    freqs = [np.fft.fftfreq(s) for s in shape[:-1]] + [np.fft.rfftfreq(shape[-1])]
    k2 = np.zeros(tuple(len(f) for f in freqs))
    for i, f in enumerate(freqs):
        sl = [None] * len(freqs)
        sl[i] = slice(None)
        k2 = k2 + np.square(f)[tuple(sl)]
    k = np.sqrt(k2)
    amp = np.zeros_like(k)
    nz = k > 0
    amp[nz] = k[nz] ** (slope / 2.0)  # power ~ k^slope -> amplitude k^(slope/2)
    noise = rng.normal(size=k.shape) + 1j * rng.normal(size=k.shape)
    x = np.fft.irfftn(amp * noise, s=shape, axes=tuple(range(len(shape))))
    x = x / (np.abs(x).max() + 1e-30)
    return x.astype(dtype)


def velocity_field(shape: Sequence[int], seed: int = 0,
                   slope: float = -5.0 / 3.0) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Three-component turbulence-like velocity field (Kolmogorov slope)."""
    return (gaussian_field(shape, slope, seed),
            gaussian_field(shape, slope, seed + 1),
            gaussian_field(shape, slope, seed + 2))


# dataset proxies with the paper's dimensions (Table 1), scaled by `factor`
DATASETS = {
    "nyx": dict(shape=(512, 512, 512), n_vars=6, slope=-1.8),
    "letkf": dict(shape=(98, 1200, 1200), n_vars=3, slope=-2.2),
    "miranda": dict(shape=(256, 384, 384), n_vars=3, slope=-3.0),
    "isabel": dict(shape=(100, 500, 500), n_vars=3, slope=-2.0),
    "jhtdb": dict(shape=(1024, 2048, 2048), n_vars=3, slope=-5.0 / 3.0),
}


def dataset_proxy(name: str, factor: int = 8, n_vars: int | None = None,
                  seed: int = 0):
    """Shrunk-by-``factor`` stand-in for a paper dataset (per-axis divide)."""
    spec = DATASETS[name]
    shape = tuple(max(s // factor, 16) for s in spec["shape"])
    nv = n_vars if n_vars is not None else spec["n_vars"]
    return [gaussian_field(shape, spec["slope"], seed + 7 * i) for i in range(nv)]
