"""Progressive MDR checkpointing: atomic, async, elastic, precision-on-demand.

Layout:  <dir>/step_<N>/
            <leafname>.mdr     IEEE-bitplane refactored tensor (or .raw)
            manifest.json      written LAST -> a checkpoint is valid iff
                               its manifest exists (atomic commit)

* resume:      load(..., rel_error=None) is BIT-EXACT (all planes)
* warm-start:  load(..., rel_error=1e-2) reads the sign/exponent + top
               mantissa plane groups only — a fraction of the bytes
* elastic:     tensors are stored logically (unsharded); loading under any
               mesh/sharding just device_puts with the new NamedShardings.
               (At real multi-host scale each host would write its shard
               files; the manifest schema already carries per-leaf shape so
               shard-merging is a pure extension.)
* async:       snapshot-to-host happens on the caller thread (cheap);
               encode+write runs on a background thread.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.ckpt import bitcast_codec as bc
from repro.core import lossless as ll

_SANITIZE = re.compile(r"[^\w.\-]+")


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return _SANITIZE.sub("_", ".".join(parts)) or "leaf"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, arrays = [], []
    seen = {}
    for path, leaf in leaves:
        n = _leaf_name(path)
        if n in seen:
            seen[n] += 1
            n = f"{n}__{seen[n]}"
        else:
            seen[n] = 0
        names.append(n)
        arrays.append(leaf)
    return names, arrays, treedef


def save(ckpt_dir: str | Path, step: int, tree: Any,
         hybrid: ll.HybridConfig = ll.HybridConfig(),
         meta: Optional[Dict] = None) -> Path:
    """Synchronous atomic save."""
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    # sweep stale tmp dirs from OTHER steps' crashed saves: a killed writer
    # leaves .tmp_step_M behind forever (only the same-step path above would
    # clean it), silently leaking a full checkpoint of disk per crash
    if ckpt_dir.exists():
        for stale in ckpt_dir.glob(".tmp_step_*"):
            if stale != tmp:
                shutil.rmtree(stale, ignore_errors=True)
    tmp.mkdir(parents=True)
    names, arrays, _ = _flatten(tree)
    manifest: Dict[str, Any] = {"step": step, "leaves": {}, "meta": meta or {},
                                "time": time.time()}
    for name, leaf in zip(names, arrays):
        arr = np.asarray(leaf)
        entry: Dict[str, Any] = {"dtype": str(arr.dtype),
                                 "shape": list(arr.shape)}
        if str(arr.dtype) in bc._FMT and arr.size >= 1024:
            r = bc.exact_refactor(arr, hybrid=hybrid)
            blob = bc.exact_to_bytes(r)
            entry["codec"] = "mdr"
            entry["file"] = f"{name}.mdr"
            entry["stored_bytes"] = len(blob)
            entry["raw_bytes"] = arr.nbytes
        else:
            blob = arr.tobytes()
            entry["codec"] = "raw"
            entry["file"] = f"{name}.raw"
            entry["stored_bytes"] = len(blob)
            entry["raw_bytes"] = arr.nbytes
        (tmp / entry["file"]).write_bytes(blob)
        manifest["leaves"][name] = entry
    # commit: manifest last, then atomic rename
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    best = None
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and (d / "manifest.json").exists():
            s = int(d.name.split("_")[1])
            best = s if best is None else max(best, s)
    return best


def load(ckpt_dir: str | Path, step: int, like: Any,
         rel_error: Optional[float] = None,
         shardings: Optional[Any] = None) -> Tuple[Any, Dict]:
    """Load into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: matching pytree of NamedShardings for
    elastic placement (optional).  Returns (tree, stats)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    names, like_arrays, treedef = _flatten(like)
    sh_leaves = None
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
    out = []
    bytes_read = 0
    bytes_full = 0
    for i, name in enumerate(names):
        entry = manifest["leaves"][name]
        blob = (d / entry["file"]).read_bytes()
        if entry["codec"] == "mdr":
            r = bc.exact_from_bytes(blob)
            arr, nb = bc.exact_retrieve(r, rel_error=rel_error)
            bytes_read += nb
        else:
            arr = np.frombuffer(blob, dtype=entry["dtype"]).reshape(entry["shape"])
            bytes_read += len(blob)
        bytes_full += entry["stored_bytes"]
        if sh_leaves is not None:
            arr = jax.device_put(arr, sh_leaves[i])
        out.append(arr)
    stats = {"bytes_read": bytes_read, "bytes_full": bytes_full,
             "step": manifest["step"], "read_fraction":
                 bytes_read / max(bytes_full, 1)}
    return jax.tree_util.tree_unflatten(treedef, out), stats


class AsyncCheckpointer:
    """Snapshot on the caller thread, encode+write in the background."""

    def __init__(self, ckpt_dir: str | Path):
        self.ckpt_dir = Path(ckpt_dir)
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, meta: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)  # snapshot

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, meta=meta)
            except BaseException as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
