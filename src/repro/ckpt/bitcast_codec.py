"""Exact progressive codec for checkpoint tensors: IEEE-bitplane refactoring.

Weights are bitcast to their integer bit patterns and bitplane-encoded
MSB-first (sign, exponent, mantissa).  A *prefix* of planes is a valid
truncated-mantissa approximation with bounded RELATIVE error; the FULL set of
planes restores the tensor BIT-EXACTLY — which is what training resume needs,
while evaluation/serving restores can stop early:

  planes_kept >= 1 + n_exp + k   ->   relative error <= 2^-k
  (fp32: n_exp=8, 23 mantissa planes; bf16: n_exp=8, 7 mantissa planes)

Sign+exponent planes are always fetched together (min prefix 1+n_exp): a
truncated exponent would not be an approximation at all.  Plane groups are
compressed with the paper's Algorithm-2 hybrid codec — exponent planes are
highly redundant across a weight tensor (Huffman), low mantissa planes are
noise (Direct Copy), which is exactly the distribution the hybrid targets.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lossless as ll
from repro.kernels import ops as kops

_FMT = {
    "float32": dict(bits=32, n_exp=8, view=np.uint32),
    "bfloat16": dict(bits=16, n_exp=8, view=np.uint16),
    "float16": dict(bits=16, n_exp=5, view=np.uint16),
    "int32": dict(bits=32, n_exp=31, view=np.uint32),  # exact only
    # fp64 (Miranda): 64 planes as two uint32 limbs — the hi limb
    # (sign+11exp+20 mantissa) is the progressive prefix, the lo limb is the
    # exact tail fetched only for bit-exact restores / rel < 2^-20
    "float64": dict(bits=64, n_exp=11, view=np.uint64),
}


@dataclasses.dataclass
class ExactRefactored:
    dtype: str
    shape: Tuple[int, ...]
    n_bits: int
    n_exp: int
    group_planes: List[int]
    groups: List[ll.Segment]

    @property
    def stored_bytes(self) -> int:
        return sum(g.stored_bytes for g in self.groups)

    def min_planes(self) -> int:
        return 1 + self.n_exp

    def planes_for_rel_error(self, rel: Optional[float]) -> int:
        if rel is None or rel <= 0:
            return self.n_bits
        k = max(int(np.ceil(-np.log2(rel))), 0)
        return min(self.min_planes() + k, self.n_bits)


def exact_refactor(x: np.ndarray, hybrid: ll.HybridConfig = ll.HybridConfig(),
                   design: str = "register_block", backend: str = "auto"
                   ) -> ExactRefactored:
    dt = str(x.dtype)
    fmt = _FMT[dt]
    bits = fmt["bits"]
    raw64 = np.asarray(x).reshape(-1).view(fmt["view"])
    if bits == 64:
        hi = (raw64 >> np.uint64(32)).astype(np.uint32)
        lo = (raw64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        p_hi = np.asarray(kops.encode_bitplanes(jnp.asarray(hi), 32, design,
                                                backend=backend))
        p_lo = np.asarray(kops.encode_bitplanes(jnp.asarray(lo), 32, design,
                                                backend=backend))
        planes = np.concatenate([p_hi, p_lo], axis=0)
    else:
        raw = raw64.astype(np.uint32)
        planes = np.asarray(kops.encode_bitplanes(jnp.asarray(raw), bits,
                                                  design, backend=backend))
    group_planes: List[int] = []
    left = bits
    while left:
        g = min(hybrid.group_size, left)
        group_planes.append(g)
        left -= g
    groups = []
    row = 0
    for g in group_planes:
        blob = planes[row:row + g].reshape(-1).view(np.uint8)
        seg = ll.compress_group(blob, hybrid)
        seg.meta["n_planes"] = g
        seg.meta["n_words"] = planes.shape[1]
        groups.append(seg)
        row += g
    return ExactRefactored(dtype=dt, shape=tuple(x.shape), n_bits=bits,
                           n_exp=fmt["n_exp"], group_planes=group_planes,
                           groups=groups)


def exact_retrieve(r: ExactRefactored, rel_error: Optional[float] = None,
                   design: str = "register_block", backend: str = "auto"
                   ) -> Tuple[np.ndarray, int]:
    """Reconstruct to <= rel_error (None = bit-exact).  Returns (arr, bytes_read)."""
    want = max(r.planes_for_rel_error(rel_error), r.min_planes())
    rows, got, nbytes = [], 0, 0
    for g, seg in zip(r.group_planes, r.groups):
        if got >= want:
            break
        w = seg.meta["n_words"]
        rows.append(ll.decompress_group(seg).view(np.uint32).reshape(-1, w))
        nbytes += seg.stored_bytes
        got += g
    planes = np.concatenate(rows, axis=0)
    n = int(np.prod(r.shape)) if r.shape else 1
    fmt = _FMT[r.dtype]
    if r.n_bits == 64:
        p = planes.shape[0]
        hi = np.asarray(kops.decode_bitplanes(jnp.asarray(planes[:min(p, 32)]),
                                              32, n, design, backend=backend))
        if p > 32:
            lo = np.asarray(kops.decode_bitplanes(jnp.asarray(planes[32:]),
                                                  32, n, design,
                                                  backend=backend))
        else:
            lo = np.zeros(n, np.uint32)
        raw = (hi.astype(np.uint64) << np.uint64(32)) | lo.astype(np.uint64)
        out = raw.view(np.float64).astype(r.dtype)
    else:
        raw = np.asarray(kops.decode_bitplanes(jnp.asarray(planes), r.n_bits,
                                               n, design, backend=backend))
        out = raw.astype(np.uint32).astype(fmt["view"]).view(r.dtype)
    return out.reshape(r.shape), nbytes


# ------------------------------------------------------------ serialization --

def exact_to_bytes(r: ExactRefactored) -> bytes:
    import struct
    parts = [struct.pack("<I", 0x4D445231)]
    db = r.dtype.encode()
    parts.append(struct.pack("<i", len(db)) + db)
    parts.append(struct.pack("<iii", r.n_bits, r.n_exp, len(r.shape)))
    if r.shape:
        parts.append(struct.pack(f"<{len(r.shape)}q", *r.shape))
    parts.append(struct.pack("<i", len(r.groups)))
    for g, gp in zip(r.groups, r.group_planes):
        gb = g.to_bytes()
        parts.append(struct.pack("<iq", gp, len(gb)) + gb)
    return b"".join(parts)


def exact_from_bytes(buf: bytes) -> ExactRefactored:
    import struct
    off = 4
    (ld,) = struct.unpack_from("<i", buf, off); off += 4
    dtype = buf[off:off + ld].decode(); off += ld
    n_bits, n_exp, nd = struct.unpack_from("<iii", buf, off); off += 12
    shape = struct.unpack_from(f"<{nd}q", buf, off) if nd else ()
    off += 8 * nd
    (ng,) = struct.unpack_from("<i", buf, off); off += 4
    groups, gp = [], []
    for _ in range(ng):
        g_planes, lg = struct.unpack_from("<iq", buf, off)
        off += struct.calcsize("<iq")
        groups.append(ll.Segment.from_bytes(buf[off:off + lg])); off += lg
        gp.append(g_planes)
    return ExactRefactored(dtype=dtype, shape=tuple(int(s) for s in shape),
                           n_bits=n_bits, n_exp=n_exp, group_planes=gp,
                           groups=groups)
