"""jit'd dispatch wrappers for the bitplane kernels.

Backend selection:
  'auto'             -> Pallas kernel on TPU, pure-jnp reference on CPU/GPU
  'pallas'           -> Pallas compiled (TPU)
  'pallas_interpret' -> Pallas interpret mode (CPU validation of the kernel body)
  'jnp'              -> pure-jnp reference (also the fast CPU path)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import bitplane as _bp

_DEFAULT_BACKEND = "auto"


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return backend


def config_kwargs(config) -> dict:
    """Kernel-facing kwargs of a ``repro.tune.RefactorConfig`` (duck-typed
    so this module stays import-light): expand with ``**`` into any
    encode/decode call below.  The single point coupling the kernel knob
    names to the config schema."""
    return {"design": config.design, "backend": config.backend,
            "tiles_per_block": config.tiles_per_block,
            "unroll": config.unroll}


@functools.partial(jax.jit, static_argnames=("num_planes", "design", "backend",
                                             "tiles_per_block", "unroll"))
def encode_bitplanes(mag: jax.Array, num_planes: int,
                     design: str = "register_block",
                     backend: str = _DEFAULT_BACKEND,
                     tiles_per_block: int = 8,
                     unroll: str = "butterfly") -> jax.Array:
    """(N,) uint32 magnitudes -> (num_planes, W) packed planes (MSB-first)."""
    b = _resolve(backend)
    if b == "jnp":
        return _ref.encode(mag, num_planes, design)
    return _bp.encode_pallas(mag, num_planes, design,
                             tiles_per_block=tiles_per_block, unroll=unroll,
                             interpret=(b == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("num_planes_total", "n", "design",
                                             "backend", "tiles_per_block",
                                             "unroll"))
def decode_bitplanes(planes: jax.Array, num_planes_total: int, n: int,
                     design: str = "register_block",
                     backend: str = _DEFAULT_BACKEND,
                     tiles_per_block: int = 8,
                     unroll: str = "butterfly") -> jax.Array:
    """(P, W) plane prefix -> (n,) uint32 magnitudes truncated to P planes."""
    b = _resolve(backend)
    if b == "jnp":
        return _ref.decode(planes, num_planes_total, n, design)
    return _bp.decode_pallas(planes, num_planes_total, n, design,
                             tiles_per_block=tiles_per_block, unroll=unroll,
                             interpret=(b == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("num_planes", "design", "backend",
                                             "tiles_per_block", "unroll"))
def encode_bitplanes_batch(mags: jax.Array, num_planes: int,
                           design: str = "register_block",
                           backend: str = _DEFAULT_BACKEND,
                           tiles_per_block: int = 8,
                           unroll: str = "butterfly") -> jax.Array:
    """(B, N) uint32 magnitudes -> (B, num_planes, W): one vmapped launch for
    B same-length encodes — the write-side twin of ``decode_bitplanes_batch``.
    Used by the fused write engine (``core.refactor_fused``) to encode every
    same-padded-size piece of a chunk in a single dispatch."""
    return jax.vmap(lambda m: encode_bitplanes(
        m, num_planes, design, backend, tiles_per_block, unroll))(mags)


@functools.partial(jax.jit, static_argnames=("num_planes_total", "n", "design",
                                             "backend", "tiles_per_block",
                                             "unroll"))
def decode_bitplanes_batch(planes: jax.Array, num_planes_total: int, n: int,
                           design: str = "register_block",
                           backend: str = _DEFAULT_BACKEND,
                           tiles_per_block: int = 8,
                           unroll: str = "butterfly") -> jax.Array:
    """(B, P, W) plane prefixes -> (B, n): one vmapped launch for B
    same-shape decodes — used by ``store.service.reconstruct_many`` to share
    kernel launches across chunks, variables, and sessions."""
    return jax.vmap(lambda p: decode_bitplanes(
        p, num_planes_total, n, design, backend, tiles_per_block, unroll))(planes)


def _shard_batch(fn, batch: jax.Array, mesh, axis: str):
    """Run a batched bitplane op under a mesh axis via ``shard_map``.

    ``batch``'s leading dimension is split across ``mesh``'s ``axis``; each
    device traces the same jitted batch op over its rows (collective-free,
    so results are bitwise placement-independent).  The thin wrapper is what
    lets the encode/decode batch ops trace under a mesh axis: their
    ``static_argnames`` jits can't be handed to ``shard_map`` directly with
    per-call statics bound."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as shd  # local: keep graph flat

    size = mesh.shape[axis]
    if int(batch.shape[0]) % size != 0:
        raise ValueError(
            f"batch dim {batch.shape[0]} not divisible by mesh axis "
            f"{axis!r} of size {size}")
    return shd.shard_map(fn, mesh=mesh, in_specs=P(axis),
                         out_specs=P(axis), check_vma=False)(batch)


def encode_bitplanes_sharded(mags: jax.Array, num_planes: int,
                             design: str = "register_block",
                             backend: str = _DEFAULT_BACKEND,
                             tiles_per_block: int = 8,
                             unroll: str = "butterfly", *,
                             mesh, axis: str = "chunk") -> jax.Array:
    """``encode_bitplanes_batch`` sharded over a mesh axis: (B, N) rows
    split across the axis's devices, each encoding its shard in place with
    no collectives.  B must divide by the axis size.  Bit-identical to the
    unsharded batch op (tests/test_sharded.py)."""
    return _shard_batch(
        lambda m: encode_bitplanes_batch(m, num_planes, design, backend,
                                         tiles_per_block, unroll),
        mags, mesh, axis)


def decode_bitplanes_sharded(planes: jax.Array, num_planes_total: int, n: int,
                             design: str = "register_block",
                             backend: str = _DEFAULT_BACKEND,
                             tiles_per_block: int = 8,
                             unroll: str = "butterfly", *,
                             mesh, axis: str = "chunk") -> jax.Array:
    """``decode_bitplanes_batch`` sharded over a mesh axis: (B, P, W) plane
    prefixes split across the axis's devices, decoded shard-local with no
    collectives.  B must divide by the axis size."""
    return _shard_batch(
        lambda p: decode_bitplanes_batch(p, num_planes_total, n, design,
                                         backend, tiles_per_block, unroll),
        planes, mesh, axis)


def decode_bitplanes_offset(planes: jax.Array, num_planes_total: int, n: int,
                            plane_offset: int,
                            design: str = "register_block",
                            backend: str = _DEFAULT_BACKEND,
                            tiles_per_block: int = 8,
                            unroll: str = "butterfly") -> jax.Array:
    """Decode a plane-group slice that sits at ``plane_offset`` rows into the
    MSB-first stack: row ``j`` of ``planes`` carries magnitude bit
    ``num_planes_total - 1 - (plane_offset + j)``.

    The returned (n,) uint32 magnitudes hold ONLY those bits — OR-ing the
    results of disjoint slices reproduces the full-stack decode exactly
    (integer bits are disjoint), which is what makes the incremental read
    path (``core.reconstruct``) bit-exact with the full-decode oracle.

    Implemented as a truncated-total decode: shifting the total by the offset
    shifts every row's bit position identically, so the existing kernels (and
    their jit caches, Pallas included) are reused as-is."""
    return decode_bitplanes(planes, num_planes_total - plane_offset, n,
                            design, backend, tiles_per_block, unroll)


def decode_bitplanes_offset_batch(planes: jax.Array, num_planes_total: int,
                                  n: int, plane_offset: int,
                                  design: str = "register_block",
                                  backend: str = _DEFAULT_BACKEND,
                                  tiles_per_block: int = 8,
                                  unroll: str = "butterfly") -> jax.Array:
    """(B, P, W) same-offset plane-group slices -> (B, n) partial magnitudes:
    the batched form of ``decode_bitplanes_offset`` (one vmapped launch).
    Used by the incremental reconstruction engine to decode newly fetched
    groups across pieces, chunks, variables, and sessions in one call."""
    return decode_bitplanes_batch(planes, num_planes_total - plane_offset, n,
                                  design, backend, tiles_per_block, unroll)
