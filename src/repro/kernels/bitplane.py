"""Pallas TPU bitplane encode/decode kernels — the paper's three designs.

TPU adaptation (see DESIGN.md §2):

``register_block`` (paper §4.3, the winner; default)
    Input tile (32, 128) int32 in VMEM: lane ``l`` owns the 32 lane-strided
    elements ``x[0..31, l]`` (flat indices ``128 i + l``) — the TPU analogue
    of a thread loading warp-strided elements: loads are fully coalesced and
    encoding needs NO cross-lane communication.  Per lane we perform a 32x32
    bit-matrix transpose in vector registers; ``unroll='naive'`` is the
    direct O(B^2) extraction, ``unroll='butterfly'`` the 5-stage
    Hacker's-Delight transpose (O(B log B)) — the §Perf kernel iteration.

``locality`` (paper §4.1)
    Input tile (128, 32): each sublane-row owns 32 *consecutive* elements
    (one output word).  The narrow 32-lane block and the cross-lane
    reduction are the TPU analogue of the design's uncoalesced loads; it
    preserves bit-order locality (better downstream compressibility).

``shuffle`` (paper §4.2)
    Same (128, 32) layout, but the word is assembled with a log2(32)-step
    cross-lane shift tree (``pltpu.roll``) — the TPU-native analogue of the
    warp shift-reduce.  Warp ``ballot``/``match-any``/``redux`` have no TPU
    equivalent (no warp-collective datapath); documented in DESIGN.md.

Formats match ``ref.py`` bit-exactly (portability contract): `locality` and
`shuffle` share the consecutive-element format; `register_block` uses the
lane-strided interleave.  Planes are MSB-first.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_SUB = 32
TILE_LANE = 128
TILE = TILE_SUB * TILE_LANE


def _u32(x):
    return x.astype(jnp.uint32)


# ------------------------------------------------------ register_block ----

def _transpose32_butterfly(rows):
    """5-stage bit-matrix transpose of 32 uint32 'rows' (vector over lanes).

    rows[i] holds bit b of element i at bit position b.  Returns t with
    t[b] holding bit b of element i at bit position i.
    """
    a = list(rows)
    m = jnp.uint32(0x0000FFFF)
    j = 16
    while j:
        k = 0
        while k < 32:
            t = (a[k] ^ (a[k + j] >> jnp.uint32(j))) & m
            a[k] = a[k] ^ t
            a[k + j] = a[k + j] ^ (t << jnp.uint32(j))
            k = (k + j + 1) & ~j
        j >>= 1
        m = m ^ (m << jnp.uint32(j)) if j else m
    # Orientation (probed empirically, asserted in tests):
    #   in[i] bit b  ->  out[31-b] bit (31-i)
    # so callers reverse the ELEMENT-side row index to get plane words whose
    # bit i corresponds to element i.
    return a


def _encode_register_block_kernel(x_ref, out_ref, *, num_planes: int,
                                  tiles: int, unroll: str):
    x = _u32(x_ref[...])  # (32*tiles, 128)
    for t in range(tiles):
        xt = x[t * TILE_SUB:(t + 1) * TILE_SUB, :]  # (32, 128)
        if unroll == "butterfly":
            # left-align so magnitude bit (num_planes-1) sits at bit 31;
            # reverse element rows so plane-word bit i <- element i.
            shift = jnp.uint32(32 - num_planes)
            rows = [xt[31 - i, :] << shift for i in range(TILE_SUB)]
            tr = _transpose32_butterfly(rows)
            for j in range(num_planes):
                out_ref[j, t * TILE_LANE:(t + 1) * TILE_LANE] = tr[j]
        else:
            for j in range(num_planes):
                b = jnp.uint32(num_planes - 1 - j)
                acc = jnp.zeros((TILE_LANE,), jnp.uint32)
                for i in range(TILE_SUB):
                    acc = acc | (((xt[i, :] >> b) & jnp.uint32(1)) << jnp.uint32(i))
                out_ref[j, t * TILE_LANE:(t + 1) * TILE_LANE] = acc


def _decode_register_block_kernel(p_ref, out_ref, *, num_planes_total: int,
                                  tiles: int, unroll: str):
    p = _u32(p_ref[...])  # (P, 128*tiles)
    P = p.shape[0]
    for t in range(tiles):
        pt = p[:, t * TILE_LANE:(t + 1) * TILE_LANE]
        if unroll == "butterfly":
            rows = [jnp.zeros((TILE_LANE,), jnp.uint32)] * 32
            for j in range(P):
                rows[j] = pt[j, :]
            tr = _transpose32_butterfly(rows)
            shift = jnp.uint32(32 - num_planes_total)
            for i in range(TILE_SUB):
                out_ref[t * TILE_SUB + i, :] = tr[31 - i] >> shift
        else:
            for i in range(TILE_SUB):
                acc = jnp.zeros((TILE_LANE,), jnp.uint32)
                for j in range(P):
                    b = jnp.uint32(num_planes_total - 1 - j)
                    acc = acc | (((pt[j, :] >> jnp.uint32(i)) & jnp.uint32(1)) << b)
                out_ref[t * TILE_SUB + i, :] = acc


# ------------------------------------------------------------ locality ----

def _encode_locality_kernel(x_ref, out_ref, *, num_planes: int, tiles: int):
    x = _u32(x_ref[...])  # (128*tiles, 32): row = one word's 32 consecutive elems
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))[None, :]
    for j in range(num_planes):
        b = jnp.uint32(num_planes - 1 - j)
        bits = (x >> b) & jnp.uint32(1)
        out_ref[j, :] = jnp.sum(bits * weights, axis=1).astype(jnp.uint32)


def _decode_locality_kernel(p_ref, out_ref, *, num_planes_total: int, tiles: int):
    p = _u32(p_ref[...])  # (P, 128*tiles)
    P = p.shape[0]
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :]
    acc = jnp.zeros((p.shape[1], 32), jnp.uint32)
    for j in range(P):
        b = jnp.uint32(num_planes_total - 1 - j)
        bits = (p[j, :, None] >> shifts) & jnp.uint32(1)
        acc = acc | (bits << b)
    out_ref[...] = acc


# ------------------------------------------------------------- shuffle ----

def _encode_shuffle_kernel(x_ref, out_ref, *, num_planes: int, tiles: int):
    """Shift-tree word assembly across the 32-lane axis (warp-shuffle analogue)."""
    from jax.experimental.pallas import tpu as pltpu  # noqa: F401  (roll)
    x = _u32(x_ref[...])  # (128*tiles, 32)
    lane = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 1)
    for j in range(num_planes):
        b = jnp.uint32(num_planes - 1 - j)
        w = ((x >> b) & jnp.uint32(1)) << lane  # thread i contributes bit i
        s = 16
        while s >= 1:
            # tree-reduce OR across lanes (roll is a cyclic lane shift)
            w = w | jnp.roll(w, -s, axis=1)
            s //= 2
        out_ref[j, :] = w[:, 0]


# ------------------------------------------------------------ wrappers ----

def _grid_pad(n: int, tiles_per_block: int) -> int:
    block_elems = TILE * tiles_per_block
    return (n + block_elems - 1) // block_elems


@functools.partial(
    jax.jit,
    static_argnames=("num_planes", "design", "tiles_per_block", "unroll", "interpret"),
)
def encode_pallas(mag: jax.Array, num_planes: int, design: str = "register_block",
                  tiles_per_block: int = 8, unroll: str = "butterfly",
                  interpret: bool = False) -> jax.Array:
    """(N,) uint32 -> (num_planes, W) uint32.  N is padded to a whole grid."""
    n = mag.shape[0]
    g = _grid_pad(n, tiles_per_block)
    n_pad = g * TILE * tiles_per_block
    mag = jnp.pad(mag.astype(jnp.uint32), (0, n_pad - n))
    W = n_pad // 32
    wpb = TILE_LANE * tiles_per_block  # words per block

    if design == "register_block":
        x2 = mag.reshape(-1, TILE_LANE)  # (32*tiles*g, 128)
        kern = functools.partial(_encode_register_block_kernel,
                                 num_planes=num_planes, tiles=tiles_per_block,
                                 unroll=unroll)
        in_spec = pl.BlockSpec((TILE_SUB * tiles_per_block, TILE_LANE),
                               lambda i: (i, 0))
    else:
        x2 = mag.reshape(-1, 32)  # (128*tiles*g, 32): consecutive elems per row
        if design == "locality":
            kern = functools.partial(_encode_locality_kernel,
                                     num_planes=num_planes, tiles=tiles_per_block)
        else:
            kern = functools.partial(_encode_shuffle_kernel,
                                     num_planes=num_planes, tiles=tiles_per_block)
        in_spec = pl.BlockSpec((TILE_LANE * tiles_per_block, 32), lambda i: (i, 0))

    out = pl.pallas_call(
        kern,
        grid=(g,),
        in_specs=[in_spec],
        out_specs=pl.BlockSpec((num_planes, wpb), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((num_planes, W), jnp.uint32),
        interpret=interpret,
    )(x2)
    # canonical plane width pads N to one tile, not a whole grid block
    w_canon = (n + ((-n) % TILE)) // 32
    return out[:, :w_canon]


@functools.partial(
    jax.jit,
    static_argnames=("num_planes_total", "n", "design", "tiles_per_block",
                     "unroll", "interpret"),
)
def decode_pallas(planes: jax.Array, num_planes_total: int, n: int,
                  design: str = "register_block", tiles_per_block: int = 8,
                  unroll: str = "butterfly", interpret: bool = False) -> jax.Array:
    """(P, W) uint32 prefix -> (n,) uint32 truncated magnitudes."""
    P, W = planes.shape
    g = _grid_pad(W * 32, tiles_per_block)
    wpb = TILE_LANE * tiles_per_block
    if W % wpb:  # pad planes to a whole grid block (zero words decode to 0)
        planes = jnp.pad(planes, ((0, 0), (0, g * wpb - W)))
        W = g * wpb

    if design == "register_block":
        kern = functools.partial(_decode_register_block_kernel,
                                 num_planes_total=num_planes_total,
                                 tiles=tiles_per_block, unroll=unroll)
        out2 = pl.pallas_call(
            kern,
            grid=(g,),
            in_specs=[pl.BlockSpec((P, wpb), lambda i: (0, i))],
            out_specs=pl.BlockSpec((TILE_SUB * tiles_per_block, TILE_LANE),
                                   lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((W * 32 // TILE_LANE, TILE_LANE),
                                           jnp.uint32),
            interpret=interpret,
        )(planes)
        return out2.reshape(-1)[:n]
    else:
        kern = functools.partial(_decode_locality_kernel,
                                 num_planes_total=num_planes_total,
                                 tiles=tiles_per_block)
        out2 = pl.pallas_call(
            kern,
            grid=(g,),
            in_specs=[pl.BlockSpec((P, wpb), lambda i: (0, i))],
            out_specs=pl.BlockSpec((TILE_LANE * tiles_per_block, 32),
                                   lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((W, 32), jnp.uint32),
            interpret=interpret,
        )(planes)
        return out2.reshape(-1)[:n]
