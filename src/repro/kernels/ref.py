"""Pure-jnp reference (oracle) implementations of bitplane packing.

Two on-disk FORMATS exist (the paper's three *execution* designs map onto
them — `shuffle` shares the `locality` format, exactly as warp-ballot
produces consecutive-element words on GPUs):

``locality``  word ``w`` of plane ``j`` holds bit ``(Bm-1-j)`` of elements
              ``32w .. 32w+31`` (consecutive elements -> bit lanes).

``register_block``  elements are processed in tiles of 32x128 = 4096; within
              tile ``t`` the element at (slot i, lane l), i.e. flat index
              ``4096 t + 128 i + l``, contributes bit ``i`` of word
              ``128 t + l``.  This is the paper's lane-strided interleave
              (warp width 32 -> TPU lane width 128): loads are fully
              coalesced and no cross-lane exchange is needed.

Planes are stored MSB-first: plane 0 carries bit (num_planes-1), so a
*prefix* of planes is exactly a precision-truncated representation.

All refs operate on uint32 magnitudes and return ``(num_planes, W) uint32``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

TILE_SUB = 32      # slots per lane (bits per packed word)
TILE_LANE = 128    # TPU lane width
TILE = TILE_SUB * TILE_LANE  # 4096 elements per tile

_IOTA32 = None


def _pad_to(x: jnp.ndarray, multiple: int) -> jnp.ndarray:
    n = x.shape[0]
    rem = (-n) % multiple
    if rem:
        x = jnp.concatenate([x, jnp.zeros((rem,), dtype=x.dtype)])
    return x


def padded_words(n: int, design: str = "register_block") -> int:
    """Number of uint32 words per plane for an n-element input.

    All designs pad N to a whole 4096-element tile so the three formats have
    identical plane sizes (and TPU-friendly 128-word alignment)."""
    n_pad = n + ((-n) % TILE)
    return n_pad // 32


# ---------------------------------------------------------------- locality --

def encode_locality(mag: jnp.ndarray, num_planes: int) -> jnp.ndarray:
    """(N,) uint32 -> (num_planes, N/32) uint32, consecutive-element words."""
    x = _pad_to(mag.astype(jnp.uint32), TILE).reshape(-1, 32)  # (W, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)  # bit position within word
    planes = []
    for j in range(num_planes):
        b = num_planes - 1 - j
        bits = (x >> jnp.uint32(b)) & jnp.uint32(1)
        planes.append(jnp.sum(bits << shifts[None, :], axis=1, dtype=jnp.uint32))
    return jnp.stack(planes)


def decode_locality(planes: jnp.ndarray, num_planes_total: int, n: int) -> jnp.ndarray:
    """(P, W) uint32 prefix -> (n,) uint32 magnitude truncated to top P planes."""
    p, w = planes.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    out = jnp.zeros((w, 32), dtype=jnp.uint32)
    for j in range(p):
        b = num_planes_total - 1 - j
        bits = (planes[j][:, None] >> shifts[None, :]) & jnp.uint32(1)
        out = out | (bits << jnp.uint32(b))
    return out.reshape(-1)[:n]


# ---------------------------------------------------------- register_block --

def encode_register_block(mag: jnp.ndarray, num_planes: int) -> jnp.ndarray:
    """(N,) uint32 -> (num_planes, N/32) uint32, lane-strided interleave."""
    x = _pad_to(mag.astype(jnp.uint32), TILE).reshape(-1, TILE_SUB, TILE_LANE)
    shifts = jnp.arange(TILE_SUB, dtype=jnp.uint32)  # slot i -> bit i
    planes = []
    for j in range(num_planes):
        b = num_planes - 1 - j
        bits = (x >> jnp.uint32(b)) & jnp.uint32(1)  # (T, 32, 128)
        words = jnp.sum(bits << shifts[None, :, None], axis=1, dtype=jnp.uint32)
        planes.append(words.reshape(-1))  # (T*128,)
    return jnp.stack(planes)


def decode_register_block(planes: jnp.ndarray, num_planes_total: int, n: int) -> jnp.ndarray:
    p, w = planes.shape
    t = w // TILE_LANE
    pw = planes.reshape(p, t, TILE_LANE)
    shifts = jnp.arange(TILE_SUB, dtype=jnp.uint32)
    out = jnp.zeros((t, TILE_SUB, TILE_LANE), dtype=jnp.uint32)
    for j in range(p):
        b = num_planes_total - 1 - j
        bits = (pw[j][:, None, :] >> shifts[None, :, None]) & jnp.uint32(1)
        out = out | (bits << jnp.uint32(b))
    return out.reshape(-1)[:n]


ENCODERS = {"locality": encode_locality, "shuffle": encode_locality,
            "register_block": encode_register_block}
DECODERS = {"locality": decode_locality, "shuffle": decode_locality,
            "register_block": decode_register_block}


def encode(mag, num_planes: int, design: str = "register_block"):
    return ENCODERS[design](mag, num_planes)


def decode(planes, num_planes_total: int, n: int, design: str = "register_block"):
    return DECODERS[design](planes, num_planes_total, n)


# NumPy twin used by tests as an independent oracle --------------------------

def encode_np(mag: np.ndarray, num_planes: int, design: str = "register_block") -> np.ndarray:
    mag = np.asarray(mag, dtype=np.uint32)
    n_pad = len(mag) + ((-len(mag)) % TILE)
    x = np.zeros(n_pad, dtype=np.uint32)
    x[: len(mag)] = mag
    out = np.zeros((num_planes, n_pad // 32), dtype=np.uint32)
    for j in range(num_planes):
        b = num_planes - 1 - j
        bits = (x >> b) & 1
        if design == "register_block":
            br = bits.reshape(-1, TILE_SUB, TILE_LANE)
            words = np.zeros((br.shape[0], TILE_LANE), dtype=np.uint32)
            for i in range(TILE_SUB):
                words |= br[:, i, :].astype(np.uint32) << i
            out[j] = words.reshape(-1)
        else:
            br = bits.reshape(-1, 32)
            words = np.zeros(br.shape[0], dtype=np.uint32)
            for i in range(32):
                words |= br[:, i].astype(np.uint32) << i
            out[j] = words
    return out
