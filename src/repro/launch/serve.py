"""Serving launcher: batched prefill + decode with the KV cache
(GQA / MLA-absorbed / SSM-state / rolling-SWA per arch).

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-v2-236b \
        --smoke --batch 4 --prompt-len 32 --new-tokens 16 [--kv-int8]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_archs, smoke_config
from repro.models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--kv-int8", action="store_true",
                    help="exponent-aligned int8 KV cache (halves cache reads)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    if args.kv_int8 and cfg.mla is None and cfg.ssm is None:
        cfg = dataclasses.replace(cfg, kv_cache_int8_scale=8.0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new_tokens
    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    vis = None
    if cfg.cross_attn_period:
        vis = jax.random.normal(rng, (args.batch, cfg.n_vision_tokens,
                                      cfg.d_model), jnp.bfloat16)
    prefill = jax.jit(lambda p, t: model.prefill(p, tokens=t, max_len=max_len,
                                                 vision_states=vis))
    decode = jax.jit(lambda p, c, i, t: model.decode_step(p, c, i, t,
                                                          vision_states=vis))
    logits, cache = prefill(params, prompts)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    toks = [tok]
    for i in range(args.new_tokens - 1):
        logits, cache = decode(params, cache, jnp.int32(args.prompt_len + i), tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"{cfg.name}: {args.new_tokens - 1} decode steps, "
          f"{dt * 1e3 / max(args.new_tokens - 1, 1):.1f} ms/token "
          f"(incl. first-call compile)")
    print(jnp.concatenate(toks, axis=1))


if __name__ == "__main__":
    main()
