"""Serving launcher: batched prefill + decode with the KV cache
(GQA / MLA-absorbed / SSM-state / rolling-SWA per arch).  The loop itself
lives in repro.launch.driver (shared with examples/serve_batch.py).

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-v2-236b \
        --smoke --batch 4 --prompt-len 32 --new-tokens 16 [--kv-int8]
"""
import argparse
import dataclasses

from repro.configs.base import get_config, list_archs, smoke_config
from repro.launch.driver import serve_greedy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--kv-int8", action="store_true",
                    help="exponent-aligned int8 KV cache (halves cache reads)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step")
    if args.kv_int8 and cfg.mla is None and cfg.ssm is None:
        cfg = dataclasses.replace(cfg, kv_cache_int8_scale=8.0)
    res = serve_greedy(cfg, args.batch, args.prompt_len, args.new_tokens)

    print(f"{cfg.name}: {args.new_tokens - 1} decode steps, "
          f"{res.ms_per_token:.1f} ms/token (incl. first-call compile)")
    print(res.tokens)


if __name__ == "__main__":
    main()
