"""Per-(arch x shape x mesh) execution policy.

Resolves: batch sharding axes, microbatch count, optimizer-state dtype,
decode-cache length (rolling window for SWA) — the knobs that make every
cell fit and compile on the production meshes.

TP-friendliness: archs whose head count divides the 16-way model axis shard
attention heads over 'model'; qwen2 (28H) and rwkv6 (40H) keep attention
head-local and instead fold the model axis into data parallelism when the
global batch allows (documented roofline consequence; a §Perf hillclimb
candidate)."""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class CellPolicy:
    batch_axes: Tuple[str, ...]     # mesh axes sharding the batch dim
    n_micro: int                    # gradient-accumulation steps (train)
    opt_state_dtype: str
    cache_len: int                  # decode cache length (window for SWA)
    seq_shard: bool = False         # decode KV cache sharded over 'model'
    notes: str = ""


def tp_friendly(cfg: ModelConfig) -> bool:
    return cfg.n_heads % 16 == 0


def _axes_product(mesh_axes, axes: Tuple[str, ...]) -> int:
    sizes = dict(mesh_axes)
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n


def cell_policy(cfg: ModelConfig, shape: ShapeConfig, mesh) -> CellPolicy:
    mesh_axes = tuple(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes: Tuple[str, ...] = tuple(a for a in ("pod", "data")
                                     if a in mesh.axis_names)
    batch_axes = dp_axes
    # non-TP archs: absorb the model axis into data parallelism if divisible
    if not tp_friendly(cfg):
        cand = dp_axes + ("model",)
        if shape.global_batch % _axes_product(mesh_axes, cand) == 0:
            batch_axes = cand
    # inputs must shard evenly: trim axes until the batch divides
    while batch_axes and shape.global_batch % _axes_product(mesh_axes, batch_axes):
        batch_axes = batch_axes[:-1]

    dp = _axes_product(mesh_axes, batch_axes)
    per_dev_seqs = max(shape.global_batch // dp, 1)

    # microbatching: target ~1 sequence per device per microbatch for >=50B
    # models at 4k, more for small models
    big = cfg.param_count() >= 5e10 if cfg.n_layers else False
    target = 1 if big else max(1, 8192 // max(shape.seq_len, 1))
    n_micro = max(per_dev_seqs // max(target, 1), 1) if shape.kind == "train" else 1

    opt_dtype = "bfloat16" if (cfg.n_layers and cfg.param_count() >= 5e10) \
        else "float32"

    cache_len = shape.seq_len
    if cfg.attn_window and shape.kind == "decode":
        cache_len = min(cfg.attn_window, shape.seq_len)

    # flash-decoding: shard big attention caches over 'model' on the L axis
    has_attn_cache = not (cfg.ssm and cfg.ssm.kind == "rwkv6")
    seq_shard = (shape.kind == "decode" and has_attn_cache
                 and cache_len > 8192 and "model" in mesh.axis_names
                 and cache_len % mesh.shape.get("model", 1) == 0)

    notes = ""
    if not tp_friendly(cfg):
        notes = ("attention head-local (H % 16 != 0); model axis folded into "
                 f"DP where divisible (batch_axes={batch_axes})")
    return CellPolicy(batch_axes=batch_axes, n_micro=n_micro,
                      opt_state_dtype=opt_dtype, cache_len=cache_len,
                      seq_shard=seq_shard, notes=notes)
