"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
        --steps 30 [--grad-compress-planes 8] [--ckpt-dir DIR]

``--smoke`` uses the reduced config (CPU-runnable); without it the full
config is built (requires a real TPU slice; on CPU it will OOM).  The
production meshes come from launch/mesh.py; on a multi-host TPU slice run
one process per host (jax.distributed.initialize) with the same command.
MoE archs train with the shard_map EP dispatch (§Perf default).
"""
import argparse
import dataclasses

from repro.configs.base import get_config, list_archs, smoke_config
from repro.models.model import Model, count_params
from repro.optim import adamw
from repro.train.loop import Trainer, TrainerConfig, synthetic_data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--grad-compress-planes", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch="shard_map"))
    model = Model(cfg)
    print(f"{cfg.name}: {count_params(cfg) / 1e6:.1f}M params")
    trainer = Trainer(
        model,
        adamw.AdamWConfig(lr=3e-4, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps),
        TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, log_every=5,
                      grad_compress_planes=args.grad_compress_planes),
        synthetic_data(cfg, args.batch, args.seq))
    res = trainer.run()
    for m in res["metrics"]:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"{m['dt'] * 1e3:8.1f} ms")


if __name__ == "__main__":
    main()
