"""Shared greedy prefill+decode serving driver.

Both ``examples/serve_batch.py`` and ``repro.launch.serve`` run the same
loop (jit prefill, argmax, jit single-token decode steps against the cache);
this module is the single implementation so the two entry points cannot
drift.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model


@dataclasses.dataclass
class ServeResult:
    tokens: jax.Array          # (B, new_tokens) greedy token ids
    prefill_s: float           # wall time of the prefill call (incl. compile)
    decode_s: float            # wall time of all decode steps
    new_tokens: int

    @property
    def ms_per_token(self) -> float:
        return self.decode_s * 1e3 / max(self.new_tokens - 1, 1)


def build_inputs(cfg: ModelConfig, batch: int, prompt_len: int,
                 seed: int = 1) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Random prompt ids (+ vision states when the arch cross-attends)."""
    rng = jax.random.PRNGKey(seed)
    prompts = jax.random.randint(rng, (batch, prompt_len), 0, cfg.vocab_size)
    vis = None
    if cfg.cross_attn_period:
        vis = jax.random.normal(rng, (batch, cfg.n_vision_tokens,
                                      cfg.d_model), jnp.bfloat16)
    return prompts, vis


def serve_greedy(cfg: ModelConfig, batch: int, prompt_len: int,
                 new_tokens: int, param_seed: int = 0,
                 input_seed: int = 1) -> ServeResult:
    """Prefill a batch of prompts, then greedy-decode ``new_tokens`` ids."""
    if cfg.encoder_only:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(param_seed))
    max_len = prompt_len + new_tokens
    prompts, vis = build_inputs(cfg, batch, prompt_len, seed=input_seed)

    prefill = jax.jit(lambda p, t: model.prefill(p, tokens=t, max_len=max_len,
                                                 vision_states=vis))
    decode = jax.jit(lambda p, c, i, t: model.decode_step(p, c, i, t,
                                                          vision_states=vis))

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    prefill_s = time.time() - t0

    generated = [tok]
    t0 = time.time()
    for i in range(new_tokens - 1):
        logits, cache = decode(params, cache, jnp.int32(prompt_len + i), tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.time() - t0

    return ServeResult(tokens=jnp.concatenate(generated, axis=1),
                       prefill_s=prefill_s, decode_s=decode_s,
                       new_tokens=new_tokens)
