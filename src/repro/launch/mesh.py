"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state.  The dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512
before any jax import; everything else sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) ('data','model') single pod; (2,16,16) ('pod','data','model')
    across two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1):
    """Small mesh over however many (host) devices are available — used by
    smoke tests, elastic-restore tests and the weak-scaling benchmark."""
    shape = tuple(x for x in (pod, data, model))
    axes = ("pod", "data", "model")
    if pod == 1:
        shape, axes = (data, model), ("data", "model")
    return jax.make_mesh(shape, axes)
