import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
host devices let jax.make_mesh build the production meshes; every step is
lowered from ShapeDtypeStructs (zero allocation), compiled, and the compiled
artifact is mined for:

  * memory_analysis()  -- per-device argument/output/temp bytes (fits check)
  * cost_analysis()    -- per-device HLO FLOPs / bytes accessed
  * collective wire bytes -- parsed from the optimized HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute, with ring
    wire-cost factors and replica-group sizes)

Results are cached as JSON under out/dryrun/ for the roofline report.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--list] [--force]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

OUT_DIR = Path(__file__).resolve().parents[3] / "out" / "dryrun"


def run_cell(arch: str, shape_name: str, mesh_kind: str, force: bool = False,
             opts: str = ""):
    """opts: comma list of §Perf hillclimb switches applied on top of the
    baseline config: moe_shard_map | tp_only_params | kv_int8."""
    import dataclasses as _dc
    from repro.configs.base import (SHAPES, get_config, input_specs,
                                    cell_supported)
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_production_mesh
    from repro.launch.policy import cell_policy
    from repro.models.model import Model
    from repro.optim import adamw
    from repro.train import step as steps

    opt_list = [o for o in opts.split(",") if o]
    tag = ("__" + "_".join(sorted(opt_list))) if opt_list else ""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out_path = OUT_DIR / f"{arch}__{shape_name}__{mesh_kind}{tag}.json"
    if out_path.exists() and not force:
        res = json.loads(out_path.read_text())
        print(f"[cached] {arch} x {shape_name} x {mesh_kind}: {res['status']}")
        return res

    cfg = get_config(arch)
    if "moe_shard_map" in opt_list and cfg.moe is not None:
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, dispatch="shard_map"))
    if "kv_int8" in opt_list:
        cfg = _dc.replace(cfg, kv_cache_int8_scale=8.0)
    drop_fsdp = "tp_only_params" in opt_list
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "opts": opt_list, "status": "skip", "reason": why}
    if not ok:
        out_path.write_text(json.dumps(rec, indent=1))
        print(f"[skip]   {arch} x {shape_name}: {why}")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        with shd.use_mesh(mesh):
            policy = cell_policy(cfg, shape, mesh)
            for o in opt_list:  # §Perf: microbatch-count override (micro<N>)
                if o.startswith("micro"):
                    policy = _dc.replace(policy, n_micro=int(o[5:]))
            model = Model(cfg)
            pshape = model.shape_structs()
            pshard = jax.tree.map(
                lambda s: jax.NamedSharding(mesh, s),
                model.partition_specs(drop_fsdp=drop_fsdp))
            bspecs = input_specs(cfg, shape)
            bshard = steps.batch_shardings(bspecs, policy, mesh)

            if shape.kind == "train":
                opt_cfg = adamw.AdamWConfig(state_dtype=policy.opt_state_dtype)
                ostate_shape = jax.eval_shape(
                    lambda p: adamw.init(p, opt_cfg), pshape)
                ospecs = adamw.state_partition_specs(model.partition_specs())
                oshard = jax.tree.map(
                    lambda s: jax.NamedSharding(mesh, s), ospecs,
                    is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
                fn = steps.make_train_step(model, opt_cfg, policy)
                jfn = jax.jit(fn, in_shardings=(pshard, oshard, bshard),
                              out_shardings=(pshard, oshard, None))
                lowered = jfn.lower(pshape, ostate_shape, bspecs)
            elif shape.kind == "prefill":
                fn = steps.make_prefill_step(model)
                jfn = jax.jit(fn, in_shardings=(pshard, bshard),
                              out_shardings=None)
                lowered = jfn.lower(pshape, bspecs)
            else:  # decode
                import dataclasses as _dc
                cfg2 = _dc.replace(cfg, seq_shard_decode=policy.seq_shard,
                                   decode_batch_axes=tuple(policy.batch_axes))
                model = Model(cfg2)
                cache = model.init_cache_structs(shape.global_batch,
                                                 policy.cache_len)
                cshard = steps.cache_shardings(cache, policy, mesh)
                fn = steps.make_decode_step(model)
                jfn = jax.jit(fn, in_shardings=(pshard, cshard, None, bshard),
                              out_shardings=(None, cshard))
                idx = jax.ShapeDtypeStruct((), jnp.int32)
                lowered = jfn.lower(pshape, cache, idx, bspecs)

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            from repro.launch.hlo_analysis import HloAnalysis
            hlo = compiled.as_text()
            import gzip
            (OUT_DIR / f"{arch}__{shape_name}__{mesh_kind}{tag}.hlo.gz").write_bytes(
                gzip.compress(hlo.encode(), 3))
            ana = HloAnalysis(hlo).summary()

            rec.update({
                "status": "ok",
                "policy": {"batch_axes": list(policy.batch_axes),
                           "n_micro": policy.n_micro,
                           "opt_state_dtype": policy.opt_state_dtype,
                           "cache_len": policy.cache_len,
                           "notes": policy.notes},
                "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1),
                "memory": {
                    "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                    "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                    "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                    "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
                },
                # loop-aware analyzer numbers (the roofline inputs)
                "flops_per_device": ana["flops_per_device"],
                "hbm_bytes_per_device": ana["hbm_bytes_per_device"],
                "collectives": {
                    "wire_bytes_per_device":
                        ana["collective_wire_bytes_per_device"],
                    "by_kind": ana["collectives_by_kind"],
                    "top": ana["top_collectives"],
                },
                # raw XLA numbers for reference (while bodies counted once)
                "xla_cost_analysis": {
                    "flops": cost.get("flops", 0.0),
                    "bytes_accessed": cost.get("bytes accessed", 0.0),
                },
            })
            print(f"[ok]     {arch} x {shape_name} x {mesh_kind}: "
                  f"lower {t_lower:.0f}s compile {t_compile:.0f}s "
                  f"flops/dev {rec['flops_per_device']:.3g} "
                  f"wire/dev {rec['collectives']['wire_bytes_per_device']:.3g}B")
            # the deliverable printout
            print("  memory_analysis:", {k: f"{v/1e9:.2f}GB"
                                          for k, v in rec["memory"].items()})
    except Exception as e:  # noqa: BLE001
        rec.update({"status": "fail", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
        print(f"[FAIL]   {arch} x {shape_name} x {mesh_kind}: "
              f"{type(e).__name__}: {str(e)[:300]}")
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def _group_by_kind(colls):
    out = {}
    for c in colls:
        d = out.setdefault(c["kind"], {"count": 0, "wire_bytes": 0.0})
        d["count"] += 1
        d["wire_bytes"] += c["wire_bytes"]
    return out


def reanalyze():
    """Recompute analyzer outputs from saved .hlo.gz (no recompiles)."""
    import gzip
    from repro.launch.hlo_analysis import HloAnalysis
    for p in sorted(OUT_DIR.glob("*.json")):
        hp = p.with_suffix("").with_suffix("")  # strip .json
        hz = OUT_DIR / (p.name[:-5] + ".hlo.gz")
        if not hz.exists():
            continue
        rec = json.loads(p.read_text())
        if rec.get("status") != "ok":
            continue
        ana = HloAnalysis(gzip.decompress(hz.read_bytes()).decode()).summary()
        rec["flops_per_device"] = ana["flops_per_device"]
        rec["hbm_bytes_per_device"] = ana["hbm_bytes_per_device"]
        rec["collectives"] = {
            "wire_bytes_per_device": ana["collective_wire_bytes_per_device"],
            "by_kind": ana["collectives_by_kind"],
            "top": ana["top_collectives"],
        }
        p.write_text(json.dumps(rec, indent=1))
        print("reanalyzed", p.name)


def main():
    from repro.configs.base import SHAPES, list_archs

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--reanalyze", action="store_true")
    ap.add_argument("--opts", default="", help="comma list: moe_shard_map,tp_only_params,kv_int8")
    args = ap.parse_args()

    if args.reanalyze:
        reanalyze()
        return

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.list:
        for a in archs:
            for s in shapes:
                print(a, s)
        return

    n_fail = 0
    for a in archs:
        for s in shapes:
            for m in meshes:
                rec = run_cell(a, s, m, force=args.force, opts=args.opts)
                n_fail += rec["status"] == "fail"
    print(f"dry-run complete; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
