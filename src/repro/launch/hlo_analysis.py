"""Mini HLO analyzer: loop-aware FLOPs / HBM-traffic / collective-wire-bytes
from optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts every ``while`` body ONCE, which
under-reports scanned-layer models by orders of magnitude.  This analyzer
walks the computation call graph (ENTRY -> fusions/whiles/conditionals),
multiplies by each while's ``backend_config={"known_trip_count"}``, and sums:

  * flops: 2 * prod(result dims) * prod(contracting dims)  per dot
  * bytes: operand+result sizes of materializing ops (dot, fusion boundary,
    collective, dynamic-(update-)slice, copy, scatter, gather) — an HBM
    traffic proxy (on-chip reuse inside a fusion is free, matching how VMEM
    works on the real target)
  * collectives: ring wire-cost per device
      all-gather S_out*(n-1)/n | all-reduce 2S(n-1)/n | reduce-scatter
      S_out*(n-1) | all-to-all S*(n-1)/n | collective-permute S

Validated against analytic model FLOPs in tests (agreement within the remat
factor).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1,
                "u8": 1, "pred": 1, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(f64|s64|u64|c64|f32|s32|u32|bf16|f16|s16|u16|s8|u8|"
                       r"pred|token|f8e4m3fn|f8e5m2)\[([\d,]*)\]")
_VAR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OP_RE = re.compile(r"\s*([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'known_trip_count[\\":{]+n[\\":]+(\d+)')
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUEFALSE_RE = re.compile(r"(?:true|false)_computation=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
# NOTE: 'copy'/'transpose' excluded — XLA loop-state copies of invariant scan
# inputs are elided/double-buffered on real hardware; counting them charges
# the full xs array per scan step (orders-of-magnitude overcount).
_MATERIALIZING = ("dot", "fusion", "dynamic-slice",
                  "dynamic-update-slice", "scatter", "gather",
                  "convolution") + COLLECTIVE_OPS


def _shapes_in(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
        out.append((m.group(1), dims))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    var: str
    type_str: str
    op: str
    line: str


@dataclasses.dataclass
class CollectiveRec:
    kind: str
    wire_bytes: float
    payload_bytes: int
    group_size: int
    count: float  # executions incl. loop multiplier


class HloAnalysis:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self.flops = 0.0
        self.bytes = 0.0
        self.collectives: List[CollectiveRec] = []
        if self.entry:
            self._walk(self.entry, 1.0)

    # ------------------------------------------------------------- parsing --
    def _parse(self, text: str):
        cur: Optional[str] = None
        for raw in text.splitlines():
            if not raw:
                continue
            if not raw[0].isspace():
                m = _COMP_HDR_RE.match(raw)
                if m:
                    cur = m.group(2)
                    self.computations[cur] = []
                    if m.group(1):
                        self.entry = cur
                continue
            if cur is None:
                continue
            s = raw.strip()
            if s == "}":
                cur = None
                continue
            mi = _VAR_RE.match(raw)
            if not mi:
                continue
            rest = raw[mi.end():]
            # strip /*index=N*/ comments (tuple types embed '=' in them)
            rest = re.sub(r"/\*.*?\*/", "", rest)
            # type is either a (possibly nested) tuple '(...)' or one token
            if rest.lstrip().startswith("("):
                depth = 0
                for j, ch in enumerate(rest):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            break
                type_str, tail = rest[:j + 1], rest[j + 1:]
            else:
                parts = rest.lstrip().split(" ", 1)
                type_str = parts[0]
                tail = parts[1] if len(parts) > 1 else ""
            mo = _OP_RE.match(tail)
            if mo:
                self.computations[cur].append(
                    Instr(var=mi.group(1), type_str=type_str,
                          op=mo.group(1), line=s))

    # -------------------------------------------------------------- walking --
    def _symtab(self, comp: str) -> Dict[str, str]:
        return {i.var: i.type_str for i in self.computations.get(comp, [])}

    def _walk(self, comp: str, mult: float):
        instrs = self.computations.get(comp, [])
        sym = {i.var: i.type_str for i in instrs}
        for i in instrs:
            op = i.op
            if op == "dot":
                self.flops += mult * self._dot_flops(i, sym)
            if op in COLLECTIVE_OPS or any(
                    op == c + "-start" for c in COLLECTIVE_OPS):
                self._collective(i, mult)
            if op in _MATERIALIZING or op.endswith("-start"):
                self.bytes += mult * self._io_bytes(i, sym)
            # recurse
            if op == "while":
                b = _BODY_RE.search(i.line)
                trip = 1
                mt = _TRIP_RE.search(i.line)
                if mt:
                    trip = int(mt.group(1))
                if b:
                    self._walk(b.group(1), mult * trip)
            elif op == "fusion":
                c = _CALLS_RE.search(i.line)
                if c:
                    self._walk_fusion(c.group(1), mult)
            elif op == "conditional":
                names = _BRANCH_RE.search(i.line)
                branches = []
                if names:
                    branches = [n.strip().lstrip("%") for n in
                                names.group(1).split(",")]
                branches += _TRUEFALSE_RE.findall(i.line)
                # conservative: most expensive branch
                best = 0.0
                best_name = None
                for bn in branches:
                    sub = HloSubCost(self, bn)
                    if sub.flops >= best:
                        best, best_name = sub.flops, bn
                if best_name:
                    self._walk(best_name, mult)
            elif op == "call":
                c = re.search(r"to_apply=%?([\w.\-]+)", i.line)
                if c:
                    self._walk(c.group(1), mult)

    def _walk_fusion(self, comp: str, mult: float):
        """Fused computations: count dots, skip per-instruction byte counting
        (fusion boundary bytes already counted at the call site)."""
        instrs = self.computations.get(comp, [])
        sym = {i.var: i.type_str for i in instrs}
        for i in instrs:
            if i.op == "dot":
                self.flops += mult * self._dot_flops(i, sym)
            elif i.op == "fusion":
                c = _CALLS_RE.search(i.line)
                if c:
                    self._walk_fusion(c.group(1), mult)

    # ------------------------------------------------------------- costing --
    def _dot_flops(self, i: Instr, sym: Dict[str, str]) -> float:
        out_shapes = _shapes_in(i.type_str)
        out_elems = 0
        for _, dims in out_shapes:
            n = 1
            for d in dims:
                n *= d
            out_elems += n
        m = re.search(r"dot\(%([\w.\-]+),", i.line)
        contract = 1
        if m and m.group(1) in sym:
            lhs_shapes = _shapes_in(sym[m.group(1)])
            if lhs_shapes:
                dims = lhs_shapes[0][1]
                mc = _CONTRACT_RE.search(i.line)
                if mc and mc.group(1):
                    for ax in mc.group(1).split(","):
                        ax = int(ax)
                        if ax < len(dims):
                            contract *= dims[ax]
        return 2.0 * out_elems * contract

    def _io_bytes(self, i: Instr, sym: Dict[str, str]) -> float:
        # slicing ops touch only the slice, not the full operand: a scan
        # reading per-step chunks must not be charged the whole array per step
        if i.op == "dynamic-slice":
            return 2.0 * _nbytes(_shapes_in(i.type_str))      # read + write slice
        if i.op == "dynamic-update-slice":
            m = re.search(r"dynamic-update-slice\(%[\w.\-]+, %([\w.\-]+)",
                          i.line)
            upd = _nbytes(_shapes_in(sym.get(m.group(1), ""))) if m else 0
            return 2.0 * upd                                   # read + write update
        if i.op == "fusion":
            return self._fusion_bytes(i, sym)
        total = _nbytes(_shapes_in(i.type_str))
        oper = i.line.split("(", 1)[1].split(")", 1)[0] if "(" in i.line else ""
        for m in re.finditer(r"%([\w.\-]+)", oper):
            v = m.group(1)
            if v in sym:
                total += _nbytes(_shapes_in(sym[v]))
        return float(total)

    def _fusion_bytes(self, i: Instr, sym: Dict[str, str]) -> float:
        """Fusion boundary traffic: result + params, except params that are
        only dynamic-sliced inside (charged at slice size), and
        scan-accumulator fusions (root dynamic-update-slice into a loop-state
        buffer) charged at update size — the buffer itself is updated in
        place, not rewritten per step."""
        c = _CALLS_RE.search(i.line)
        fused = self.computations.get(c.group(1), []) if c else []
        orig_result_bytes = float(_nbytes(_shapes_in(i.type_str)))
        result_bytes = orig_result_bytes
        is_accumulator = False
        for fi in fused:
            if fi.op == "dynamic-update-slice":
                mu = re.search(r"dynamic-update-slice\(%([\w.\-]+), %([\w.\-]+)",
                               fi.line)
                if mu:
                    fsym = {x.var: x.type_str for x in fused}
                    upd = _nbytes(_shapes_in(fsym.get(mu.group(2), "")))
                    if upd and upd < result_bytes:
                        result_bytes = 2.0 * upd
                        is_accumulator = True
                break
        total = result_bytes
        skipped_acc = False
        # param index -> (var, shape) inside the fused computation
        param_vars = {}
        for fi in fused:
            mp = re.search(r"parameter\((\d+)\)", fi.line)
            if mp:
                param_vars[int(mp.group(1))] = fi.var
        # call-site operands in order (cut before kind=/calls= attributes)
        oper_str = i.line.split("(", 1)[1].split(")", 1)[0]
        args = re.findall(r"%([\w.\-]+)", oper_str)
        for idx, arg in enumerate(args):
            if arg not in sym:
                continue
            pv = param_vars.get(idx)
            full = _nbytes(_shapes_in(sym[arg]))
            if is_accumulator and not skipped_acc and full == orig_result_bytes:
                skipped_acc = True  # the in-place accumulator operand
                continue
            if pv is None:
                total += full
                continue
            # consumers of this param inside the fusion
            sliced, other = 0, False
            for fi in fused:
                if re.search(rf"\(%{re.escape(pv)}[,)]", fi.line) or \
                   re.search(rf", %{re.escape(pv)}[,)]", fi.line):
                    if fi.op == "dynamic-slice":
                        sliced += _nbytes(_shapes_in(fi.type_str))
                    elif fi.op == "dynamic-update-slice":
                        pass  # write counted via result
                    else:
                        other = True
            total += full if (other or not sliced) else sliced
        return total

    def _collective(self, i: Instr, mult: float):
        kind = i.op.replace("-start", "")
        if kind not in COLLECTIVE_OPS:
            return
        shapes = _shapes_in(i.type_str)
        out_bytes = _nbytes(shapes[-1:]) if kind == "all-gather" and \
            len(shapes) > 1 else _nbytes(shapes)
        g = _GROUPS_IOTA_RE.search(i.line)
        if g:
            n = int(g.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(i.line)
            n = len(gl.group(1).split(",")) if gl else 1
        if n <= 1:
            return
        if kind == "all-gather":
            wire = out_bytes * (n - 1) / n
        elif kind == "all-reduce":
            wire = 2.0 * out_bytes * (n - 1) / n
        elif kind == "reduce-scatter":
            wire = out_bytes * (n - 1)
        elif kind == "all-to-all":
            wire = out_bytes * (n - 1) / n
        else:
            wire = float(out_bytes)
        self.collectives.append(CollectiveRec(kind=kind, wire_bytes=wire * mult,
                                              payload_bytes=out_bytes,
                                              group_size=n, count=mult))

    # -------------------------------------------------------------- report --
    def summary(self) -> Dict:
        by_kind: Dict[str, Dict[str, float]] = {}
        for c in self.collectives:
            d = by_kind.setdefault(c.kind, {"count": 0.0, "wire_bytes": 0.0})
            d["count"] += c.count
            d["wire_bytes"] += c.wire_bytes
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.bytes,
            "collective_wire_bytes_per_device":
                sum(c.wire_bytes for c in self.collectives),
            "collectives_by_kind": by_kind,
            "top_collectives": [dataclasses.asdict(c) for c in sorted(
                self.collectives, key=lambda c: -c.wire_bytes)[:12]],
        }


class HloSubCost:
    """Flops of one computation subtree (for conditional branch selection)."""
    def __init__(self, parent: HloAnalysis, comp: str):
        self.flops = 0.0
        instrs = parent.computations.get(comp, [])
        sym = {i.var: i.type_str for i in instrs}
        for i in instrs:
            if i.op == "dot":
                self.flops += parent._dot_flops(i, sym)
