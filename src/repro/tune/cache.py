"""On-disk autotune config cache (``out/tune/``).

Winning configs are cached per *backend fingerprint* (resolved backend +
platform + device kind + device count + jax version: anything that can
change which config wins) and per *problem key* (shape, dtype, levels) —
the same keying the tuner scores over.  Layout::

    out/tune/<fingerprint>/<problem>.json
        {"config": {...RefactorConfig...},
         "meta": {"fingerprint": ..., "problem": ..., "probe_s": ...,
                  "scores": ...}}

``DatasetWriter`` and the chunked pipelines consult the cache by default
(``cached_config``): a hit replays the tuned plan with one memoized disk
read; a miss costs one ``os.stat`` and falls back to the caller's defaults.
Nothing here ever *starts* a search — that is ``repro.tune.search.tune``,
which writes winners through ``store``.

``REPRO_TUNE_CACHE`` overrides the cache root (tests point it at a tmp dir;
CI's autotune smoke job asserts hit/miss counters across two runs).
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Sequence, Tuple

from repro.tune.config import RefactorConfig

_REPO = Path(__file__).resolve().parents[3]
_ENV = "REPRO_TUNE_CACHE"


@dataclasses.dataclass
class CacheStats:
    """Process-global hit/miss counters (thread-safe).  The autotune smoke
    benchmark asserts ``hits`` increments — and ``searches`` does not — on a
    second ``tune()`` run against a warm cache."""
    hits: int = 0
    misses: int = 0
    stores: int = 0

    def __post_init__(self):
        self._lock = threading.Lock()

    def add(self, **kw: int) -> None:
        with self._lock:
            for k, v in kw.items():
                setattr(self, k, getattr(self, k) + v)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {f.name: getattr(self, f.name)
                    for f in dataclasses.fields(self)}

    def reset(self) -> None:
        with self._lock:
            for f in dataclasses.fields(self):
                setattr(self, f.name, 0)


STATS = CacheStats()

# memo of (root, fingerprint, problem) -> Optional[RefactorConfig]: a writer
# streaming many variables with the same chunk shape stats the disk once
_MEMO: Dict[Tuple[str, str, str], Optional[RefactorConfig]] = {}
_MEMO_LOCK = threading.Lock()


def cache_root(root: Optional[os.PathLike] = None) -> Path:
    if root is not None:
        return Path(root)
    env = os.environ.get(_ENV)
    return Path(env) if env else _REPO / "out" / "tune"


def backend_fingerprint(backend: str = "auto", n_devices: int = 1) -> str:
    """Everything that can change which config wins, flattened to a slug."""
    import jax

    from repro.kernels import ops as kops
    resolved = kops._resolve(backend)
    try:
        kind = jax.devices()[0].device_kind.replace(" ", "_")
    except Exception:
        kind = "unknown"
    return (f"{resolved}-{jax.default_backend()}-{kind}"
            f"-{n_devices}dev-jax{jax.__version__}")


def problem_key(shape: Sequence[int], dtype: str = "float32",
                levels: Optional[int] = None) -> str:
    dims = "x".join(str(int(d)) for d in shape) or "scalar"
    return f"{dims}-{dtype}-L{'auto' if levels is None else int(levels)}"


def _path(root: Path, fingerprint: str, problem: str) -> Path:
    return root / fingerprint / f"{problem}.json"


def load(fingerprint: str, problem: str,
         root: Optional[os.PathLike] = None) -> Optional[RefactorConfig]:
    """Cached winner or None; memoized per (root, fingerprint, problem)."""
    r = cache_root(root)
    memo_key = (str(r), fingerprint, problem)
    with _MEMO_LOCK:
        if memo_key in _MEMO:
            hit = _MEMO[memo_key]
            STATS.add(hits=1 if hit is not None else 0,
                      misses=0 if hit is not None else 1)
            return hit
    p = _path(r, fingerprint, problem)
    cfg: Optional[RefactorConfig] = None
    try:
        cfg = RefactorConfig.from_json(json.loads(p.read_text())["config"])
    except FileNotFoundError:
        pass
    except (json.JSONDecodeError, KeyError, TypeError, ValueError):
        # a corrupt cache entry must never break a write: treat as a miss
        cfg = None
    with _MEMO_LOCK:
        _MEMO[memo_key] = cfg
    STATS.add(hits=1 if cfg is not None else 0,
              misses=0 if cfg is not None else 1)
    return cfg


def store(fingerprint: str, problem: str, config: RefactorConfig,
          meta: Optional[Dict[str, Any]] = None,
          root: Optional[os.PathLike] = None) -> Path:
    """Persist a winner (atomic rename) and refresh the memo."""
    r = cache_root(root)
    p = _path(r, fingerprint, problem)
    p.parent.mkdir(parents=True, exist_ok=True)
    payload = {"config": config.to_json(),
               "meta": dict(meta or {}, fingerprint=fingerprint,
                            problem=problem)}
    tmp = p.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(payload, indent=2, sort_keys=True))
    os.replace(tmp, p)
    with _MEMO_LOCK:
        _MEMO[(str(r), fingerprint, problem)] = config
    STATS.add(stores=1)
    return p


def invalidate_memo() -> None:
    """Drop the in-process memo (tests that rewrite cache files on disk)."""
    with _MEMO_LOCK:
        _MEMO.clear()


def cached_config(shape: Sequence[int], dtype: str = "float32",
                  levels: Optional[int] = None, backend: str = "auto",
                  n_devices: int = 1,
                  root: Optional[os.PathLike] = None
                  ) -> Optional[RefactorConfig]:
    """The one-call lookup used by ``DatasetWriter`` / the pipelines."""
    return load(backend_fingerprint(backend, n_devices),
                problem_key(shape, dtype, levels), root=root)
