"""repro.tune — unified tuning config + cost-model autotuner.

``RefactorConfig`` is the one source of truth for every tuning knob of the
write/read stack; ``as_config`` normalizes legacy loose kwargs into one.
The heavier pieces (cost model, search) load lazily so core modules can
import this package without cycles.
"""
from __future__ import annotations

from repro.tune.config import DEFAULT_CONFIG, RefactorConfig, as_config

__all__ = ["RefactorConfig", "DEFAULT_CONFIG", "as_config", "tune",
           "TuneResult", "CostModel", "cached_config"]


def __getattr__(name):
    # lazy: repro.tune.search/cost import core modules, which import THIS
    # package for the config — resolving them on first touch keeps the
    # import graph acyclic
    if name in ("tune", "TuneResult"):
        from repro.tune import search as _s
        return getattr(_s, name)
    if name == "CostModel":
        from repro.tune.cost import CostModel
        return CostModel
    if name == "cached_config":
        from repro.tune.cache import cached_config
        return cached_config
    raise AttributeError(name)
