"""RefactorConfig — the single source of truth for every tuning knob.

Every layer of the write/read stack used to take its own loose kwargs
(``tiles_per_block`` in the kernels, ``design``/``mag_bits`` in the fused
engine, ``group_size``/thresholds in the lossless engine, ``dispatch_ahead``
in the pipeline, ``mesh`` in the sharded plan).  ``RefactorConfig`` collects
them in one frozen, hashable, JSON-round-trippable dataclass:

  * the autotuner (``repro.tune.search``) searches over configs and caches
    the winner per (shape, dtype, levels, backend, n_devices);
  * ``fused_encode_plan`` is keyed on the config's program-relevant fields,
    so a tuned config compiles exactly one program;
  * ``DatasetWriter`` records the winning config per variable in the store
    manifest (``VariableEntry.plan``) so readers replay the tuned plan
    instead of re-guessing defaults.

Consuming layers accept ``config=`` alongside their legacy kwargs; explicit
legacy kwargs override the corresponding config fields (``as_config``
normalizes both spellings into one config), so the two call styles are
byte-identical for equal effective configs — property-tested in
tests/test_tune.py against the per-piece oracles.

This module must stay import-light (no jax at module scope): the kernel,
core, and store layers all import it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class RefactorConfig:
    """One tuned plan for the whole refactor chain.

    Fields with ``None`` defer to the consuming layer's default (``mag_bits``
    -> ``align.DEFAULT_MAG_BITS``, ``chunk_elems`` -> the pipeline's 1<<20,
    ``mesh_devices`` -> single-device).  Quality-affecting knobs
    (``mag_bits``) are carried but never searched by the tuner — tuning must
    not change what the user asked to store."""

    # --- kernel knobs (kernels/bitplane.py via kernels/ops.py) ---
    design: str = "register_block"
    tiles_per_block: int = 8
    unroll: str = "butterfly"
    # --- encode-chain knobs (core/refactor_fused.py, core/align.py) ---
    mag_bits: Optional[int] = None
    # --- lossless bucket policy (core/lossless.py, core/lossless_batch.py) ---
    group_size: int = 4
    size_threshold: int = 4096
    cr_threshold: float = 1.0
    # --- pipeline / mesh knobs (core/pipeline.py, core/sharded.py) ---
    dispatch_ahead: int = 2
    depth: int = 2                      # read-side overlap look-ahead
    chunk_elems: Optional[int] = None
    mesh_devices: Optional[int] = None
    # --- backend selection (kernels/ops._resolve) ---
    backend: str = "auto"

    # ------------------------------------------------------------- derived --
    def resolved_mag_bits(self) -> int:
        if self.mag_bits is not None:
            return self.mag_bits
        from repro.core import align as al  # local: keep module import-light
        return al.DEFAULT_MAG_BITS

    def hybrid(self, force: Optional[str] = None):
        """The lossless engine's ``HybridConfig`` view of this config."""
        from repro.core import lossless as ll  # local: keep import-light
        return ll.HybridConfig(group_size=self.group_size,
                               size_threshold=self.size_threshold,
                               cr_threshold=self.cr_threshold,
                               force=force)

    def replace(self, **kw: Any) -> "RefactorConfig":
        return dataclasses.replace(self, **kw)

    # the static key of the fused one-dispatch program: two configs equal on
    # these fields compile (and cache) the same jitted program
    def program_key(self) -> Tuple:
        return (self.design, self.tiles_per_block, self.unroll,
                self.mag_bits, self.group_size, self.backend)

    # ---------------------------------------------------------------- json --
    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, j: Dict[str, Any]) -> "RefactorConfig":
        """Build from a JSON dict, ignoring unknown keys (manifests written
        by future versions must stay readable — same contract as
        ``store.layout``)."""
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in j.items() if k in names})


DEFAULT_CONFIG = RefactorConfig()


def as_config(config: Optional[RefactorConfig] = None, *,
              design: Optional[str] = None,
              mag_bits: Optional[int] = None,
              hybrid=None,
              backend: Optional[str] = None,
              dispatch_ahead: Optional[int] = None,
              depth: Optional[int] = None,
              chunk_elems: Optional[int] = None,
              mesh_devices: Optional[int] = None) -> RefactorConfig:
    """Normalize a ``config=`` argument plus legacy loose kwargs into ONE
    effective ``RefactorConfig``.

    Explicit (non-None) legacy kwargs override the base config's fields —
    the most local spelling wins — so refactored call sites keep their exact
    previous behavior while the config becomes the internal currency.
    ``hybrid.force`` is intentionally NOT part of the config (it is a
    benchmark/debug override, not a tunable); callers that honor it pass it
    back through ``cfg.hybrid(force=...)``."""
    base = config if config is not None else DEFAULT_CONFIG
    upd: Dict[str, Any] = {}
    if design is not None:
        upd["design"] = design
    if mag_bits is not None:
        upd["mag_bits"] = mag_bits
    if hybrid is not None:
        upd["group_size"] = hybrid.group_size
        upd["size_threshold"] = hybrid.size_threshold
        upd["cr_threshold"] = hybrid.cr_threshold
    if backend is not None:
        upd["backend"] = backend
    if dispatch_ahead is not None:
        upd["dispatch_ahead"] = dispatch_ahead
    if depth is not None:
        upd["depth"] = depth
    if chunk_elems is not None:
        upd["chunk_elems"] = chunk_elems
    if mesh_devices is not None:
        upd["mesh_devices"] = mesh_devices
    return dataclasses.replace(base, **upd) if upd else base
