"""Roofline cost model over the fused encode program's optimized HLO.

Follows the byteprofile-analysis pattern named in ROADMAP.md: instead of
exhaustively running every candidate config, lower the candidate's fused
one-dispatch program (``core.refactor_fused.fused_encode_plan``), extract
per-op FLOPs / HBM bytes / collective wire bytes from the optimized HLO with
the previously orphaned ``launch.hlo_analysis``, and score it against
hardware peaks::

    t_model = max(flops / peak_flops, bytes / hbm_bw) + wire / link_bw

Absolute peaks are nominal per platform (``NOMINAL_PEAKS`` — the TPU row is
the same v5e numbers ``benchmarks/roofline.py`` publishes; that module
imports them from here so the calibration artifact and the cost model can
never disagree).  Absolute accuracy does not matter for the tuner: the model
only *ranks* candidates, and the few measured probe runs
(``repro.tune.search``) both calibrate the scale and decide the winner.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, Optional, Sequence, Tuple

from repro.tune.config import RefactorConfig

# nominal hardware peaks per jax platform (flops/s, HBM bytes/s, link
# bytes/s).  TPU: v5e-class chip — the numbers benchmarks/roofline.py
# publishes.  CPU/GPU rows are order-of-magnitude placeholders; probe
# calibration absorbs the error.
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


@dataclasses.dataclass(frozen=True)
class Peaks:
    flops: float
    hbm_bw: float
    link_bw: float


NOMINAL_PEAKS: Dict[str, Peaks] = {
    "tpu": Peaks(PEAK_FLOPS, HBM_BW, LINK_BW),
    "gpu": Peaks(60e12, 2e12, 100e9),
    "cpu": Peaks(1e11, 3e10, 1e10),
}


# where a machine's measured roofline calibration lives; overridable so CI
# jobs and tests can point the tuner at a specific artifact
ROOFLINE_ARTIFACT_ENV = "REPRO_ROOFLINE_JSON"
DEFAULT_ROOFLINE_ARTIFACT = os.path.join("out", "benchmarks",
                                         "roofline.json")


def calibrated_peaks(platform: str,
                     path: Optional[str] = None) -> Optional[Peaks]:
    """This machine's measured effective peaks from its roofline artifact.

    ``benchmarks/roofline.py`` probes the fused program and publishes a
    ``calibrated`` section — nominal peaks divided by the fitted model
    scale, i.e. the peak rates at which THIS machine actually moved the
    program's bytes/flops.  When the artifact exists and matches the
    platform, the cost model starts from those instead of the hard-coded
    nominal constants (ROADMAP autotuner-deepening item), so candidate
    rankings reflect the machine rather than a v5e spec sheet.

    Returns ``None`` (nominal fallback) when the artifact is absent,
    unreadable, for another platform, or carries non-finite/zero rates —
    a corrupt artifact must never poison the tuner."""
    path = path if path is not None else os.environ.get(
        ROOFLINE_ARTIFACT_ENV, DEFAULT_ROOFLINE_ARTIFACT)
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
        cal = doc["calibrated"]
        if cal.get("platform") != platform:
            return None
        peaks = Peaks(float(cal["flops"]), float(cal["hbm_bw"]),
                      float(cal["link_bw"]))
    except (OSError, ValueError, KeyError, TypeError):
        return None
    vals = (peaks.flops, peaks.hbm_bw, peaks.link_bw)
    if not all(math.isfinite(v) and v > 0 for v in vals):
        return None
    return peaks


def platform_peaks(platform: Optional[str] = None) -> Peaks:
    """Peaks for scoring: the machine's calibrated roofline artifact when
    one is present (``calibrated_peaks``), else the nominal platform row."""
    if platform is None:
        import jax
        platform = jax.default_backend()
    cal = calibrated_peaks(platform)
    if cal is not None:
        return cal
    return NOMINAL_PEAKS.get(platform, NOMINAL_PEAKS["cpu"])


def fused_program_hlo(shape: Sequence[int], levels: Optional[int],
                      config: RefactorConfig, dtype: str = "float32") -> str:
    """Optimized HLO text of the candidate's fused one-dispatch program.

    Lowers against a ShapeDtypeStruct — no probe data, no execution — and
    compiles, so the text reflects what XLA will actually run (fusion
    boundaries included, which is what ``HloAnalysis`` counts)."""
    import jax
    import jax.numpy as jnp

    from repro.core import decompose as dc
    from repro.core import refactor as rf
    from repro.core import refactor_fused as rff

    shape = tuple(int(d) for d in shape)
    if levels is None:
        levels = dc.num_levels(shape)
    mag_bits = config.resolved_mag_bits()
    group_planes = tuple(rf._group_plane_split(mag_bits, config.group_size))
    plan = rff.fused_encode_plan(shape, levels, config.design, mag_bits,
                                 group_planes, config.backend,
                                 config.tiles_per_block, config.unroll)
    x = jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))
    return plan.run.lower(x).compile().as_text()


@dataclasses.dataclass(frozen=True)
class ProgramCost:
    """HLO-derived resource use of one candidate's fused program."""
    flops: float
    hbm_bytes: float
    wire_bytes: float

    def seconds(self, peaks: Peaks, scale: float = 1.0) -> float:
        """Roofline time estimate: bound by the slower of compute/memory,
        plus the collective term; ``scale`` is the probe calibration."""
        t = max(self.flops / peaks.flops, self.hbm_bytes / peaks.hbm_bw)
        return scale * (t + self.wire_bytes / peaks.link_bw)


def analyze_config(shape: Sequence[int], levels: Optional[int],
                   config: RefactorConfig,
                   dtype: str = "float32") -> ProgramCost:
    """FLOPs / bytes / wire of one candidate config's fused program."""
    from repro.launch.hlo_analysis import HloAnalysis

    ana = HloAnalysis(fused_program_hlo(shape, levels, config, dtype))
    return ProgramCost(flops=float(ana.flops), hbm_bytes=float(ana.bytes),
                       wire_bytes=float(sum(c.wire_bytes
                                            for c in ana.collectives)))


class CostModel:
    """Scores candidate configs; calibrates its scale from measured probes.

    ``score`` caches per program key — configs differing only in pipeline
    knobs (``dispatch_ahead``, thresholds) share one lowering."""

    def __init__(self, shape: Sequence[int], levels: Optional[int] = None,
                 dtype: str = "float32", peaks: Optional[Peaks] = None):
        self.shape = tuple(int(d) for d in shape)
        self.levels = levels
        self.dtype = dtype
        self.peaks = peaks if peaks is not None else platform_peaks()
        self.scale = 1.0
        self._cache: Dict[Tuple, ProgramCost] = {}

    def cost(self, config: RefactorConfig) -> ProgramCost:
        key = config.program_key()
        if key not in self._cache:
            self._cache[key] = analyze_config(self.shape, self.levels,
                                              config, self.dtype)
        return self._cache[key]

    def score(self, config: RefactorConfig) -> float:
        """Predicted seconds for one chunk through the fused program."""
        return self.cost(config).seconds(self.peaks, self.scale)

    def calibrate(self, config: RefactorConfig, measured_s: float) -> float:
        """Fit ``scale`` so the model's prediction for ``config`` matches a
        measured probe; returns the new scale.  One probe is enough to move
        predictions from nominal-peak units into this machine's units."""
        predicted = self.cost(config).seconds(self.peaks, 1.0)
        if predicted > 0 and measured_s > 0:
            self.scale = measured_s / predicted
        return self.scale
