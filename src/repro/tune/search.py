"""Autotuner: cost-model-guided search with measured probe runs.

``tune(shape, ...)`` finds the write-path config for one problem class
(shape, dtype, levels, backend, n_devices):

  1. consult the on-disk cache (``repro.tune.cache``) — a warm cache returns
     the winner with NO search, NO probes, NO compilation (the CI autotune
     smoke job asserts exactly this on its second run);
  2. on a miss, enumerate the candidate space (bitplane design x lossless
     group size x kernel tiling on accelerator backends), score every
     candidate's fused program with the HLO roofline model
     (``repro.tune.cost``) — one lowering per distinct program, no
     execution;
  3. run a handful of measured probe writes (``probes`` best-scored
     candidates, the hard-coded default ALWAYS included) through the real
     ``refactor_array`` fused path, calibrate the model's scale from the
     default's probe, then probe-search the pure-scheduling knobs the
     program's HLO cannot see: ``dispatch_ahead`` through the real chunked
     pipelined WRITE (async per-device drain windows) and the read-side
     ``depth`` through the real chunked pipelined READ of the winner's own
     blobs (overlap look-ahead + per-device drain window);
  4. cache the measured winner keyed by backend fingerprint.

The measured-best-of-probes rule keeps the tuner safe: the default config is
always a probe, so a tuned config can only tie or beat it on the probe
workload — never regress it on the machine that tuned.

Quality knobs (``mag_bits``) are never searched: tuning changes how bytes
are produced, not which bytes the user asked to keep.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.tune import cache as tcache
from repro.tune.config import DEFAULT_CONFIG, RefactorConfig
from repro.tune.cost import CostModel

DESIGNS = ("register_block", "locality", "shuffle")
GROUP_SIZES = (2, 4, 8)
TILES = (4, 8, 16)
DISPATCH_AHEAD = (1, 2, 4)
DEPTHS = (1, 2, 4)  # read-side overlap look-ahead / drain window


@dataclasses.dataclass
class SearchStats:
    """Process-global tuner counters (thread-safe).  ``searches`` counts
    actual cost-model searches — a cache hit must NOT increment it."""
    searches: int = 0
    candidates_scored: int = 0
    probes_run: int = 0

    def __post_init__(self):
        self._lock = threading.Lock()

    def add(self, **kw: int) -> None:
        with self._lock:
            for k, v in kw.items():
                setattr(self, k, getattr(self, k) + v)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {f.name: getattr(self, f.name)
                    for f in dataclasses.fields(self)}

    def reset(self) -> None:
        with self._lock:
            for f in dataclasses.fields(self):
                setattr(self, f.name, 0)


STATS = SearchStats()


@dataclasses.dataclass(frozen=True)
class TuneResult:
    config: RefactorConfig
    cache_hit: bool
    fingerprint: str
    problem: str
    # (config, model_seconds) for every scored candidate; empty on cache hit
    scores: Tuple[Tuple[RefactorConfig, float], ...] = ()
    # (config, measured_seconds) for every probe; empty on cache hit
    probes: Tuple[Tuple[RefactorConfig, float], ...] = ()
    tune_s: float = 0.0


def candidate_space(base: RefactorConfig, backend_resolved: str
                    ) -> List[RefactorConfig]:
    """Program-level candidates: design x group_size (+ kernel tiling on
    Pallas backends — the jnp reference path ignores tiles/unroll, so
    searching them on CPU would only burn compile time)."""
    out: List[RefactorConfig] = []
    tiles = TILES if backend_resolved.startswith("pallas") else (
        base.tiles_per_block,)
    unrolls = (("naive", "butterfly")
               if backend_resolved.startswith("pallas") else (base.unroll,))
    for design in DESIGNS:
        for gs in GROUP_SIZES:
            for t in tiles:
                for u in unrolls:
                    out.append(base.replace(design=design, group_size=gs,
                                            tiles_per_block=t, unroll=u))
    return out


def _probe_chunk(shape: Sequence[int], dtype: str) -> np.ndarray:
    """Deterministic smooth-plus-noise probe data: representative of the
    scientific fields the refactorer targets (compressible but not trivial),
    and identical across runs so cached winners are reproducible."""
    rng = np.random.default_rng(20240817)
    n = int(np.prod(shape, dtype=np.int64)) if len(shape) else 1
    t = np.linspace(0.0, 6.0, n, dtype=np.float64)
    x = np.sin(t) + 0.05 * rng.standard_normal(n)
    return x.astype(dtype).reshape(shape)


def _measure_write(x: np.ndarray, cfg: RefactorConfig,
                   levels: Optional[int], repeats: int = 2) -> float:
    """Measured seconds for one chunk through the fused write path with
    ``cfg`` (compile excluded: one warmup, then best-of-``repeats``)."""
    from repro.core import refactor as rf

    def once() -> float:
        t0 = time.perf_counter()
        r = rf.refactor_array(x, levels=levels, config=cfg, fused=True)
        # serialization is part of the write budget the tuner optimizes
        for _ in rf.iter_segments(r):
            pass
        return time.perf_counter() - t0

    once()  # warmup: trace + compile the candidate's program
    best = min(once() for _ in range(max(repeats, 1)))
    STATS.add(probes_run=1)
    return best


def _measure_pipeline_write(x: np.ndarray, cfg: RefactorConfig,
                            levels: Optional[int],
                            repeats: int = 2) -> float:
    """Measured seconds for a multi-chunk PIPELINED write with ``cfg`` —
    the probe that actually sees ``dispatch_ahead`` (per-device in-flight
    window + drain batch size), which a single-chunk program probe cannot.
    Compile excluded: one warmup, then best-of-``repeats``."""
    from repro.core import pipeline as pl

    def once() -> float:
        t0 = time.perf_counter()
        pipe = pl.ChunkedRefactorPipeline(levels=levels, pipelined=True,
                                          config=cfg, use_tune_cache=False)
        pipe.refactor(x)
        return time.perf_counter() - t0

    once()
    best = min(once() for _ in range(max(repeats, 1)))
    STATS.add(probes_run=1)
    return best


def _measure_pipeline_read(blobs: Sequence[bytes], cfg: RefactorConfig,
                           tol: float, repeats: int = 2) -> float:
    """Measured seconds for a multi-chunk PIPELINED read with ``cfg`` — the
    probe that actually sees ``depth`` (the overlap feeder's look-ahead AND
    the per-device drain window), which no single-chunk program probe can.
    A fresh pipeline per run: incremental readers are stateful, so reusing
    one would time the engine cache, not the decode.  Compile excluded: one
    warmup, then best-of-``repeats``."""
    from repro.core import pipeline as pl

    def once() -> float:
        t0 = time.perf_counter()
        pipe = pl.ChunkedReconstructPipeline(pipelined=True, config=cfg)
        pipe.reconstruct(blobs, tol)
        return time.perf_counter() - t0

    once()
    best = min(once() for _ in range(max(repeats, 1)))
    STATS.add(probes_run=1)
    return best


def _probe_blobs(best: RefactorConfig, n: int, levels: Optional[int],
                 dtype: str, n_chunks: int
                 ) -> Tuple[np.ndarray, List[bytes]]:
    """Refactor the read probe's data once with the winning config: the
    serialized chunk blobs every depth candidate reconstructs from."""
    from repro.core import pipeline as pl

    x = _probe_chunk((n_chunks * n,), dtype)
    blobs = pl.ChunkedRefactorPipeline(
        levels=levels, pipelined=True, config=best.replace(chunk_elems=n),
        use_tune_cache=False).refactor(x)
    return x, blobs


def _tune_read_depth(best: RefactorConfig, shape: Sequence[int],
                     dtype: str, levels: Optional[int],
                     n_chunks: int = 6
                     ) -> Tuple[RefactorConfig,
                                List[Tuple[RefactorConfig, float]]]:
    """Probe-search the read-side overlap ``depth`` through the real
    pipelined read path.

    Like ``dispatch_ahead`` on the write side, ``depth`` is pure scheduling
    (the reconstruction is bit-identical at any depth), so the HLO model is
    blind to it: refactor the probe data ONCE with the winning config, then
    reconstruct the same blobs at every candidate depth and keep the fastest
    measured one.  The adopted depth is recorded in the winner (and thus in
    the manifest ``plan``), so store readers replay it via
    ``VariableEntry.plan`` exactly as they replay the kernel tiling.
    Returns (winner, [(cfg, seconds) per depth probed])."""
    n = int(np.prod(shape, dtype=np.int64)) if len(shape) else 1
    fallback = (best if best.depth in DEPTHS
                else best.replace(depth=DEPTHS[1]))
    if n == 0:
        return fallback, []
    if levels is None:
        from repro.core import decompose as dc
        levels = dc.num_levels((n,))
    try:
        x, blobs = _probe_blobs(best, n, levels, dtype, n_chunks)
    except Exception:
        return fallback, []
    # mid-curve tolerance: deep enough that every chunk fetches several
    # plane groups (the staged-drain schedule depth actually controls)
    tol = 1e-3 * float(np.ptp(x)) if np.ptp(x) > 0 else 1e-3
    timed: List[Tuple[RefactorConfig, float]] = []
    for dp in DEPTHS:
        cfg = best.replace(depth=dp, chunk_elems=n)
        try:
            timed.append((cfg, _measure_pipeline_read(blobs, cfg, tol)))
        except Exception:
            continue
    if not timed:
        return fallback, []
    dp = min(timed, key=lambda cs: cs[1])[0].depth
    # probe chunking stays out of the winner: only the depth is adopted
    return best.replace(depth=dp), timed


def _tune_dispatch_ahead(best_prog: RefactorConfig, shape: Sequence[int],
                         dtype: str, levels: Optional[int],
                         n_chunks: int = 6
                         ) -> Tuple[RefactorConfig,
                                    List[Tuple[RefactorConfig, float]]]:
    """Probe-search the per-device in-flight window depth.

    ``dispatch_ahead`` is pure scheduling — the serialized bytes are
    identical at any depth — so the HLO cost model is blind to it and
    measurement is the only honest signal: run the winning program config
    through the real chunked pipeline (``n_chunks`` chunks of the probe
    shape, async window drains included) at every candidate depth and keep
    the fastest.  Returns (winner, [(cfg, seconds) per depth probed])."""
    n = int(np.prod(shape, dtype=np.int64)) if len(shape) else 1
    fallback = (best_prog if best_prog.dispatch_ahead in DISPATCH_AHEAD
                else best_prog.replace(dispatch_ahead=DISPATCH_AHEAD[1]))
    if n == 0:
        return fallback, []
    if levels is None:
        from repro.core import decompose as dc
        levels = dc.num_levels((n,))
    x = _probe_chunk((n_chunks * n,), dtype)
    timed: List[Tuple[RefactorConfig, float]] = []
    for da in DISPATCH_AHEAD:
        cfg = best_prog.replace(dispatch_ahead=da, chunk_elems=n)
        try:
            timed.append((cfg, _measure_pipeline_write(x, cfg, levels)))
        except Exception:
            continue
    if not timed:
        return fallback, []
    da = min(timed, key=lambda cs: cs[1])[0].dispatch_ahead
    # probe chunking stays out of the winner: only the depth is adopted
    return best_prog.replace(dispatch_ahead=da), timed


def tune(shape: Sequence[int], dtype: str = "float32",
         levels: Optional[int] = None, backend: str = "auto",
         n_devices: int = 1, probes: int = 3,
         base: Optional[RefactorConfig] = None,
         cache_root: Optional[os.PathLike] = None,
         force: bool = False) -> TuneResult:
    """Find (or recall) the winning ``RefactorConfig`` for a problem class.

    Returns a ``TuneResult``; ``result.config`` is what ``DatasetWriter``
    records in the manifest.  ``force=True`` ignores a cached winner (but
    still stores the fresh one)."""
    from repro.kernels import ops as kops

    t0 = time.perf_counter()
    shape = tuple(int(d) for d in shape)
    fp = tcache.backend_fingerprint(backend, n_devices)
    problem = tcache.problem_key(shape, dtype, levels)
    if not force:
        hit = tcache.load(fp, problem, root=cache_root)
        if hit is not None:
            return TuneResult(config=hit, cache_hit=True, fingerprint=fp,
                              problem=problem,
                              tune_s=time.perf_counter() - t0)

    STATS.add(searches=1)
    base = (base if base is not None else DEFAULT_CONFIG).replace(
        backend=backend, mesh_devices=(n_devices if n_devices > 1 else None))
    cands = candidate_space(base, kops._resolve(backend))

    model = CostModel(shape, levels, dtype)
    scored: List[Tuple[RefactorConfig, float]] = []
    for c in cands:
        try:
            scored.append((c, model.score(c)))
        except Exception:
            # a candidate that fails to lower/compile is simply not eligible
            continue
    STATS.add(candidates_scored=len(scored))
    scored.sort(key=lambda cs: cs[1])

    # measured probes: the model's top-(probes) programs, default included —
    # the winner is the best MEASURED probe, so tuned >= default by
    # construction on this machine
    probe_set: List[RefactorConfig] = [base]
    for c, _ in scored:
        if len(probe_set) >= max(probes, 1) + 1:
            break
        if c not in probe_set:
            probe_set.append(c)

    x = _probe_chunk(shape, dtype)
    measured: List[Tuple[RefactorConfig, float]] = []
    for c in probe_set:
        try:
            measured.append((c, _measure_write(x, c, levels)))
        except Exception:
            continue
    if not measured:            # pathological: keep the default, cache it
        measured = [(base, float("inf"))]
    model.calibrate(base, measured[0][1])
    best_prog = min(measured, key=lambda cs: cs[1])[0]

    # pipeline knob branch: dispatch_ahead changes host/device overlap and
    # the async drain batch size, not the program — the HLO model cannot
    # rank it, so probe it through the real chunked pipeline and keep the
    # fastest measured window depth.  If every program probe failed the
    # machine cannot be trusted to probe more: keep the default window.
    if np.isfinite(min(s for _, s in measured)):
        best, da_probes = _tune_dispatch_ahead(best_prog, shape, dtype,
                                               levels)
        # read-side scheduling twin: probe `depth` through the real
        # pipelined read of the winner's own blobs (bit-identical at any
        # depth — only wall clock distinguishes the candidates)
        best, depth_probes = _tune_read_depth(best, shape, dtype, levels)
    else:
        best = (best_prog if best_prog.dispatch_ahead in DISPATCH_AHEAD
                else best_prog.replace(dispatch_ahead=DISPATCH_AHEAD[1]))
        da_probes = []
        depth_probes = []

    tcache.store(
        fp, problem, best,
        meta={"scores": [[c.to_json(), s] for c, s in scored[:8]],
              "probes": [[c.to_json(), s] for c, s in measured],
              "dispatch_probes": [[c.dispatch_ahead, s]
                                  for c, s in da_probes],
              "depth_probes": [[c.depth, s] for c, s in depth_probes],
              "model_scale": model.scale,
              "n_candidates": len(cands)},
        root=cache_root)
    return TuneResult(config=best, cache_hit=False, fingerprint=fp,
                      problem=problem, scores=tuple(scored),
                      probes=tuple(measured),
                      tune_s=time.perf_counter() - t0)
