"""Typed counters / gauges / histograms with a snapshot API.

The metrics registry is the *numeric* half of ``repro.obs`` (the tracer is
the *temporal* half): always-on, context-local (``trace.ContextLocal``),
thread-safe, stdlib-only.  The stack records into it unconditionally —
counter increments are a dict lookup plus a lock, cheap against the device
work they annotate — and benchmarks/CI read one ``snapshot()`` dict.

Canonical names used across the stack (labels in parentheses):

  counters    ``store.bytes_raw`` / ``store.bytes_stored`` (var),
              ``codec.bytes_in`` / ``codec.bytes_out`` (codec, group),
              ``codec.groups`` (codec, group),
              ``backend.bytes_served`` / ``backend.bytes_fetched``,
              ``backend.cache_hits`` / ``backend.cache_misses``,
              ``serve.requests`` / ``serve.bytes_fetched``
  gauges      ``store.compression_ratio`` (var) — raw/stored, >= 1 is a win,
              ``write.syncs_per_chunk`` / ``write.dispatches_per_chunk``
  histograms  ``serve.retrieve_s``, ``serve.decode_s`` — p50/p99 in the
              snapshot

Labels are free-form keyword arguments; a labelled series snapshots under
``name{k=v,...}`` (sorted keys, Prometheus-flavored) so budgets in
``benchmarks/check_regressions.py`` can address exact series.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Any, Dict, Iterator, List, Optional

from repro.obs import trace as _trace

# histogram sample retention: bounded ring so long-running services cannot
# grow without bound; count/sum/min/max stay exact, quantiles are computed
# over the retained window (documented approximation)
HIST_WINDOW = 4096


def _series(name: str, labels: Dict[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


def _quantile(sorted_vals: List[float], q: float) -> float:
    """Nearest-rank quantile: the smallest value with at least q of the
    sample at or below it (p50 of [1,2,3,4] is 2, p99 is 4)."""
    if not sorted_vals:
        return 0.0
    idx = max(math.ceil(q * len(sorted_vals)) - 1, 0)
    return sorted_vals[min(idx, len(sorted_vals) - 1)]


class _Hist:
    __slots__ = ("count", "sum", "min", "max", "window")

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self.window: List[float] = []

    def observe(self, v: float) -> None:
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        if len(self.window) < HIST_WINDOW:
            self.window.append(v)
        else:
            self.window[self.count % HIST_WINDOW] = v

    def snapshot(self) -> Dict[str, float]:
        vals = sorted(self.window)
        return {"count": self.count, "sum": self.sum,
                "min": self.min if self.min is not None else 0.0,
                "max": self.max if self.max is not None else 0.0,
                "mean": self.sum / self.count if self.count else 0.0,
                "p50": _quantile(vals, 0.50), "p99": _quantile(vals, 0.99)}


class Metrics:
    """One registry: counters, gauges, histograms keyed by labelled series."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, _Hist] = {}

    def inc(self, name: str, value: float = 1, **labels: Any) -> None:
        k = _series(name, labels)
        with self._lock:
            self._counters[k] = self._counters.get(k, 0) + value

    def gauge(self, name: str, value: float, **labels: Any) -> None:
        with self._lock:
            self._gauges[_series(name, labels)] = value

    def observe(self, name: str, value: float, **labels: Any) -> None:
        k = _series(name, labels)
        with self._lock:
            h = self._hists.get(k)
            if h is None:
                h = self._hists[k] = _Hist()
            h.observe(value)

    def counter_value(self, name: str, **labels: Any) -> float:
        with self._lock:
            return self._counters.get(_series(name, labels), 0)

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {k: h.snapshot()
                               for k, h in self._hists.items()},
            }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


# Context-local registry with a process-global default: services and tests
# isolate with ``scope()``; everything else lands in the default registry.
REGISTRY = _trace.ContextLocal(Metrics)


def get() -> Metrics:
    """The current context's registry."""
    return REGISTRY.get()


def inc(name: str, value: float = 1, **labels: Any) -> None:
    REGISTRY.get().inc(name, value, **labels)


def gauge(name: str, value: float, **labels: Any) -> None:
    REGISTRY.get().gauge(name, value, **labels)


def observe(name: str, value: float, **labels: Any) -> None:
    REGISTRY.get().observe(name, value, **labels)


def snapshot() -> Dict[str, Any]:
    return REGISTRY.get().snapshot()


def reset() -> None:
    REGISTRY.get().reset()


@contextlib.contextmanager
def scope(registry: Optional[Metrics] = None) -> Iterator[Metrics]:
    """Fresh (or given) registry for the current context — benchmarks wrap
    each run so artifacts snapshot only their own numbers."""
    with REGISTRY.scope(registry) as m:
        yield m
