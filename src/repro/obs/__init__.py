"""repro.obs — unified tracing & metrics for the write/read/serving stack.

Dependency-free (stdlib only).  Three pieces:

* ``obs.trace``   — context-local span tracer (``span``/``event``/
  ``tracing``), thread-aware via ``wrap_for_thread``, plus the
  ``ContextLocal`` home for per-context stats objects.
* ``obs.metrics`` — typed counters/gauges/histograms with ``snapshot()``.
* ``obs.export``  — Chrome-trace / Perfetto JSON export with per-device
  tracks, and an optional ``jax.profiler`` bridge (``tracing(jax_profiler=
  True)``).

See docs/observability.md for the span model, metric names, and the CI
perf-regression gate (``benchmarks/check_regressions.py``).
"""
from repro.obs import export, metrics, trace
from repro.obs.export import chrome_trace, write_chrome_trace
from repro.obs.trace import (ContextLocal, Span, SpanEvent, Tracer,
                             current_span, current_tracer, event, span,
                             tracing, wrap_for_thread)

__all__ = [
    "export", "metrics", "trace",
    "chrome_trace", "write_chrome_trace",
    "ContextLocal", "Span", "SpanEvent", "Tracer",
    "current_span", "current_tracer", "event", "span", "tracing",
    "wrap_for_thread",
]
