"""Chrome-trace (``chrome://tracing`` / Perfetto) export for ``obs.trace``.

``chrome_trace(tracer)`` renders a tracer's spans and events into the
Trace Event Format dict (``{"traceEvents": [...]}``); load the written JSON
in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.

Track model: one process (pid 0, "repro").  Spans that carry a ``device``
attribute land on a ``device:<d>`` track — the sharded write path tags its
per-chunk dispatch/finish spans with the owning device ordinal, so a
2-device write renders as two device tracks and the round-boundary idle
gaps of ``ShardedRefactorPlan`` are directly visible as track whitespace.
Spans without a device land on a per-thread track named after the opening
thread (main / prefetch / serialize / feeder workers).

Point events (``host_sync``, ``dispatch``, ``backend_read``, ...) render as
instant events on their span's track, so every sync sits visually inside
the span that caused it.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.obs.trace import Span, SpanEvent, Tracer

PROCESS_NAME = "repro"


def _track_label(span: Optional[Span]) -> str:
    if span is None:
        return "events"
    dev = span.attrs.get("device")
    if dev is not None:
        return f"device:{dev}"
    return f"thread:{span.thread}"


def _jsonable(v: Any) -> Any:
    return v if isinstance(v, (bool, int, float, str, type(None))) else str(v)


def _args(attrs: Dict[str, Any]) -> Dict[str, Any]:
    return {k: _jsonable(v) for k, v in attrs.items()}


def chrome_trace(tracer: Tracer) -> Dict[str, Any]:
    """Render ``tracer`` to a Trace Event Format dict."""
    tids: Dict[str, int] = {}

    def tid(label: str) -> int:
        if label not in tids:
            # device tracks get low tids so they sort to the top of the UI
            tids[label] = (len([t for t in tids if t.startswith("device:")])
                           if label.startswith("device:")
                           else 100 + len(tids))
        return tids[label]

    t0 = tracer.t_epoch
    events: List[Dict[str, Any]] = []
    for s in tracer.spans():
        label = _track_label(s)
        end = s.t1 if s.t1 is not None else s.t0
        events.append({
            "ph": "X", "name": s.name, "cat": "span",
            "pid": 0, "tid": tid(label),
            "ts": (s.t0 - t0) * 1e6, "dur": max(end - s.t0, 0.0) * 1e6,
            "args": _args({**s.attrs, "span_id": s.span_id,
                           "parent_id": s.parent_id, "thread": s.thread}),
        })
        for ev in s.events:
            events.append(_instant(ev, tid(label), t0, span_name=s.name))
    for ev in tracer.orphan_events():
        events.append(_instant(ev, tid("events"), t0, span_name=None))

    meta = [{"ph": "M", "name": "process_name", "pid": 0,
             "args": {"name": PROCESS_NAME}}]
    for label, t in sorted(tids.items(), key=lambda kv: kv[1]):
        meta.append({"ph": "M", "name": "thread_name", "pid": 0, "tid": t,
                     "args": {"name": label}})
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def _instant(ev: SpanEvent, tid: int, t0: float,
             span_name: Optional[str]) -> Dict[str, Any]:
    args = _args(ev.attrs)
    if span_name is not None:
        args["span"] = span_name
    return {"ph": "i", "name": ev.name, "cat": "event", "s": "t",
            "pid": 0, "tid": tid, "ts": (ev.ts - t0) * 1e6, "args": args}


def device_tracks(trace_json: Dict[str, Any]) -> List[str]:
    """Names of the per-device tracks in an exported trace (test/CI hook:
    a 2-device sharded write must show two distinct device tracks)."""
    return sorted({e["args"]["name"] for e in trace_json["traceEvents"]
                   if e.get("ph") == "M" and e.get("name") == "thread_name"
                   and str(e["args"].get("name", "")).startswith("device:")})


def event_count(trace_json: Dict[str, Any], name: str) -> int:
    """Count instant events named ``name`` in an exported trace."""
    return sum(1 for e in trace_json["traceEvents"]
               if e.get("ph") == "i" and e.get("name") == name)


def write_chrome_trace(path: str, tracer: Tracer) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer), f)
    return path
