"""Context-local span tracer: attribute time, syncs, and dispatches.

The write/read/serving stack spreads its work across the main thread, the
chunked pipeline's prefetch/serialize workers, ``overlap_map`` feeder
threads, and (sharded) several devices.  Ad-hoc process-global counters
(``lossless_batch.STATS`` & friends) can say *how many* host syncs happened
but not *who* caused them — this module adds the missing attribution.

Model
-----
* A ``Tracer`` collects finished ``Span``\\ s.  Tracing is **opt-in and
  context-local**: ``with tracing() as tr:`` installs a tracer for the
  current :mod:`contextvars` context; code outside a tracing context pays
  a single ContextVar read per ``span()``/``event()`` call (the <2%%
  disabled-overhead contract, checked by ``benchmarks/refactor_benchmarks``).
* ``span(name, **attrs)`` opens a nested span.  Spans record wall-clock
  start/duration, the opening thread, free-form attributes (``chunk=3``,
  ``device=1``), and typed point events.
* ``event(name, **attrs)`` records a typed point event (``host_sync``,
  ``dispatch``, ``device_put``, ``serialize``, ``backend_read``) on the
  current span — the event inherits the span's identity, so every host sync
  in a trace knows its originating span.

Threads
-------
ContextVars do NOT flow into new threads by default.  Worker threads that
should attribute their spans to the caller's trace (and mutate the caller's
context-local stats) must run under a copy of the caller's context:
``threading.Thread(target=contextvars.copy_context().run, args=(fn,))`` —
``wrap_for_thread`` packages that idiom.  The chunked pipelines and the
store's overlap feeders already do this, so dispatch-ahead work lands in
the right trace.

``ContextLocal`` is the shared home for per-context stats objects
(``lossless_batch.STATS`` et al.): each context gets its own instance on
demand (falling back to a process-global default), and a context *copy*
shares the instance — worker threads add to the caller's counters, while
unrelated contexts never race on one global.

Everything here is stdlib-only (no jax import): the tracer must be usable
from serialization helpers and benchmarks without dragging in a backend.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import itertools
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

# Typed event names used across the stack (free-form names are fine too;
# these are the ones the exporters and benchmarks aggregate on).
EV_HOST_SYNC = "host_sync"
EV_DISPATCH = "dispatch"
EV_DEVICE_PUT = "device_put"
EV_SERIALIZE = "serialize"
EV_BACKEND_READ = "backend_read"


@dataclasses.dataclass
class SpanEvent:
    """A typed point event inside a span."""
    name: str
    ts: float                       # perf_counter seconds
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Span:
    name: str
    span_id: int
    parent_id: Optional[int]
    t0: float
    t1: Optional[float] = None
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    events: List[SpanEvent] = dataclasses.field(default_factory=list)
    thread: str = ""

    @property
    def duration_s(self) -> float:
        return (self.t1 if self.t1 is not None else time.perf_counter()) - self.t0


class Tracer:
    """Thread-safe collector of finished spans (and span-less events)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self._orphans: List[SpanEvent] = []
        self._ids = itertools.count(1)
        self.t_epoch = time.perf_counter()

    # -- recording (internal) ------------------------------------------------
    def _next_id(self) -> int:
        return next(self._ids)

    def _add_span(self, s: Span) -> None:
        with self._lock:
            self._spans.append(s)

    def _add_orphan(self, ev: SpanEvent) -> None:
        with self._lock:
            self._orphans.append(ev)

    # -- inspection ----------------------------------------------------------
    def spans(self) -> List[Span]:
        """Snapshot of all *finished* spans, in completion order."""
        with self._lock:
            return list(self._spans)

    def orphan_events(self) -> List[SpanEvent]:
        with self._lock:
            return list(self._orphans)

    def events(self, name: Optional[str] = None
               ) -> List[Tuple[Optional[Span], SpanEvent]]:
        """All (span, event) pairs, optionally filtered by event name.
        Orphan events (recorded outside any span) pair with ``None``."""
        out: List[Tuple[Optional[Span], SpanEvent]] = []
        for s in self.spans():
            for ev in s.events:
                if name is None or ev.name == name:
                    out.append((s, ev))
        for ev in self.orphan_events():
            if name is None or ev.name == name:
                out.append((None, ev))
        return out

    def event_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for _, ev in self.events():
            out[ev.name] = out.get(ev.name, 0) + 1
        return out

    def attribute_events(self, name: str = EV_HOST_SYNC,
                         key: str = "label") -> Dict[str, int]:
        """Count ``name`` events by originating span.

        The attribution key is the event's ``key`` attribute when present
        (e.g. ``host_sync(label=...)`` call-site tags), else the enclosing
        span's name, else ``"<none>"`` — this is how the benchmarks answer
        "whose syncs are these?"."""
        out: Dict[str, int] = {}
        for span_, ev in self.events(name):
            k = ev.attrs.get(key) or (span_.name if span_ else "<none>")
            out[str(k)] = out.get(str(k), 0) + 1
        return out

    def total_s(self, span_name: str) -> float:
        """Summed wall seconds of all finished spans named ``span_name``."""
        return sum(s.duration_s for s in self.spans() if s.name == span_name)

    def summary(self) -> Dict[str, Any]:
        """Compact JSON-able digest: per-span-name count/total wall seconds
        plus global event counts (what the benchmark artifacts embed)."""
        per: Dict[str, Dict[str, float]] = {}
        for s in self.spans():
            d = per.setdefault(s.name, {"count": 0, "total_s": 0.0})
            d["count"] += 1
            d["total_s"] += s.duration_s
        return {"spans": per, "events": self.event_counts(),
                "host_syncs_by_span": self.attribute_events(EV_HOST_SYNC)}


# ------------------------------------------------------------ context state --

_tracer_var: "contextvars.ContextVar[Optional[Tracer]]" = \
    contextvars.ContextVar("repro_obs_tracer", default=None)
_span_var: "contextvars.ContextVar[Optional[Span]]" = \
    contextvars.ContextVar("repro_obs_span", default=None)


def current_tracer() -> Optional[Tracer]:
    return _tracer_var.get()


def current_span() -> Optional[Span]:
    return _span_var.get()


def enabled() -> bool:
    return _tracer_var.get() is not None


@contextlib.contextmanager
def tracing(tracer: Optional[Tracer] = None,
            jax_profiler: bool = False) -> Iterator[Tracer]:
    """Install a tracer for the current context (and threads that run under
    a copy of it — see ``wrap_for_thread``).

    ``jax_profiler=True`` additionally bridges every span into
    ``jax.profiler.TraceAnnotation`` so repro spans line up with XLA's own
    traces in TensorBoard/Perfetto; it is a no-op when jax (or its profiler)
    is unavailable, keeping this module importable without jax."""
    t = tracer if tracer is not None else Tracer()
    if jax_profiler:
        t._jax_annotation = _jax_annotation_cls()  # type: ignore[attr-defined]
    tok = _tracer_var.set(t)
    try:
        yield t
    finally:
        _tracer_var.reset(tok)


@contextlib.contextmanager
def no_tracing() -> Iterator[None]:
    """Uninstall any active tracer for the dynamic extent of the block —
    the disabled-overhead measurement's off-switch (span() returns the
    shared null manager inside)."""
    tok = _tracer_var.set(None)
    try:
        yield
    finally:
        _tracer_var.reset(tok)


def _jax_annotation_cls():
    try:  # deferred: obs must import (and trace) without jax present
        from jax.profiler import TraceAnnotation
        return TraceAnnotation
    except Exception:  # noqa: BLE001 - profiler is strictly optional
        return None


class _NullSpan:
    """Shared no-op context manager: the disabled-tracing fast path."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _SpanCtx:
    __slots__ = ("_tracer", "_span", "_token", "_jax_ctx")

    def __init__(self, tracer: Tracer, name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        parent = _span_var.get()
        self._span = Span(name=name, span_id=tracer._next_id(),
                          parent_id=parent.span_id if parent else None,
                          t0=time.perf_counter(), attrs=attrs,
                          thread=threading.current_thread().name)
        self._token = None
        self._jax_ctx = None

    def __enter__(self) -> Span:
        self._token = _span_var.set(self._span)
        ann = getattr(self._tracer, "_jax_annotation", None)
        if ann is not None:
            self._jax_ctx = ann(self._span.name)
            self._jax_ctx.__enter__()
        return self._span

    def __exit__(self, *exc):
        if self._jax_ctx is not None:
            self._jax_ctx.__exit__(*exc)
        self._span.t1 = time.perf_counter()
        _span_var.reset(self._token)
        self._tracer._add_span(self._span)
        return False


def span(name: str, /, **attrs: Any):
    """Open a span under the context's tracer; near-free no-op when tracing
    is off (one ContextVar read, shared null context manager).  ``name`` is
    positional-only so an attribute may also be called ``name``
    (``span("encode.dispatch", name="vx")``)."""
    t = _tracer_var.get()
    if t is None:
        return NULL_SPAN
    return _SpanCtx(t, name, attrs)


def event(name: str, /, **attrs: Any) -> None:
    """Record a typed point event on the current span (or as an orphan on
    the tracer when no span is open).  No-op when tracing is off."""
    t = _tracer_var.get()
    if t is None:
        return
    ev = SpanEvent(name=name, ts=time.perf_counter(), attrs=attrs)
    s = _span_var.get()
    if s is not None:
        s.events.append(ev)  # span is thread-confined while open
    else:
        t._add_orphan(ev)


def wrap_for_thread(fn: Callable[..., Any]) -> Callable[..., Any]:
    """Bind ``fn`` to a copy of the *caller's* context, for use as a thread
    target: spans/events in the thread join the caller's trace, and
    ``ContextLocal`` stats mutations land in the caller's instances.  Each
    call copies the context once (a Context cannot be entered twice
    concurrently, so one copy per thread)."""
    ctx = contextvars.copy_context()

    def run(*args, **kw):
        return ctx.run(fn, *args, **kw)

    return run


# ------------------------------------------------------- context-local stats --

class ContextLocal:
    """A per-context instance of ``factory()`` with a process-global default.

    ``get()`` returns the instance installed for the current context (or the
    shared default when none is).  ``scope()`` installs a fresh (or given)
    instance for the dynamic extent of a ``with`` block — threads started
    via ``wrap_for_thread`` inside the block share the *same* instance, so
    counters from dispatch-ahead workers attribute to the scope that
    spawned them, while unrelated contexts never observe it."""

    def __init__(self, factory: Callable[[], Any]):
        self._factory = factory
        self._default = factory()
        self._var: "contextvars.ContextVar[Any]" = contextvars.ContextVar(
            f"repro_obs_ctxlocal_{id(self):x}", default=None)

    @property
    def default(self) -> Any:
        """The process-global fallback instance."""
        return self._default

    def get(self) -> Any:
        v = self._var.get()
        return self._default if v is None else v

    @contextlib.contextmanager
    def scope(self, value: Any = None) -> Iterator[Any]:
        v = self._factory() if value is None else value
        tok = self._var.set(v)
        try:
            yield v
        finally:
            self._var.reset(tok)
