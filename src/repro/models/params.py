"""Abstract parameter descriptions: one source of truth for init, sharding,
dry-run ShapeDtypeStructs and analytic parameter counts.

A model's ``abstract_params(cfg)`` returns a pytree of :class:`PSpec`; the
helpers below materialize it (random init), turn it into PartitionSpecs
(logical 'fsdp' -> ('pod','data'), 'tp' -> 'model', filtered by the current
mesh), or into ShapeDtypeStructs for ``jax.jit(...).lower``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd

Axis = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Axis, ...]             # logical: 'fsdp' | 'tp' | None per dim
    init: str = "normal"               # normal | zeros | ones
    scale: float = 1.0                 # stddev multiplier (normal)
    dtype: Optional[str] = None        # override model param dtype

    def nbytes(self, default_dtype: str) -> int:
        dt = np.dtype(self.dtype or default_dtype)
        return int(np.prod(self.shape)) * dt.itemsize

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


def _resolve_axis(a: Axis, drop_fsdp: bool = False):
    if a == "fsdp":
        return None if drop_fsdp else ("pod", "data")
    if a == "tp":
        return "model"
    return a


def pspec_to_partition(ps: PSpec, drop_fsdp: bool = False) -> P:
    return shd.spec(*[_resolve_axis(a, drop_fsdp) for a in ps.axes])


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def tree_partition_specs(tree, drop_fsdp: bool = False):
    """drop_fsdp=True gives serving-style TP-only sharding: weights live
    whole on each model shard — no per-step FSDP all-gathers (decode §Perf)."""
    return jax.tree.map(lambda p: pspec_to_partition(p, drop_fsdp), tree,
                        is_leaf=is_pspec)


def tree_shape_structs(tree, default_dtype: str):
    def f(ps: PSpec):
        return jax.ShapeDtypeStruct(ps.shape, jnp.dtype(ps.dtype or default_dtype))
    return jax.tree.map(f, tree, is_leaf=is_pspec)


def tree_init(tree, key: jax.Array, default_dtype: str):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_pspec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for ps, k in zip(leaves, keys):
        dt = jnp.dtype(ps.dtype or default_dtype)
        if ps.init == "zeros":
            v = jnp.zeros(ps.shape, dt)
        elif ps.init == "ones":
            v = jnp.ones(ps.shape, dt)
        else:
            fan_in = ps.shape[0] if len(ps.shape) > 1 else max(ps.shape[-1], 1)
            std = ps.scale / np.sqrt(fan_in)
            v = (jax.random.normal(k, ps.shape, jnp.float32) * std).astype(dt)
        out.append(v)
    return jax.tree.unflatten(treedef, out)


def tree_param_count(tree) -> int:
    total = 0
    for ps in jax.tree.leaves(tree, is_leaf=is_pspec):
        total += ps.size
    return total
