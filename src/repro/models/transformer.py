"""Layer-stack composition: pattern-based blocks scanned over depth.

Architectures are expressed as a list of *stack groups*; each group is a
repeating pattern of sub-layers scanned with stacked parameters, so the HLO
holds one copy of each distinct block body regardless of depth (compile time
and module size stay flat in n_layers):

  uniform        N x [attn/ssm + mlp]                (most archs)
  first_dense    K x dense-FFN block, (N-K) x MoE    (deepseek v2/v3)
  jamba          (N/8) x [8-layer period: 1 attn + 7 mamba, MoE every 2nd]
  vision_cross   (N/5) x [4 self-attn + 1 gated cross-attn]

Every block body is wrapped in jax.checkpoint (remat) when cfg.remat is set.
Caches thread through the same scans as stacked pytrees.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.params import PSpec, is_pspec


# --------------------------------------------------------- block templates --

def _norm_spec(cfg: ModelConfig) -> PSpec:
    return PSpec((cfg.d_model,), (None,), init="ones")


def block_abstract(cfg: ModelConfig, kind: str) -> Dict:
    """Parameter tree for one block of the given kind."""
    p: Dict[str, Any] = {"norm1": _norm_spec(cfg)}
    if kind == "attn":
        p["attn"] = L.mla_abstract(cfg) if cfg.mla else L.gqa_abstract(cfg)
        if not cfg.parallel_block:
            p["norm2"] = _norm_spec(cfg)
        p["mlp"] = L.mlp_abstract(cfg)
    elif kind == "attn_moe":
        p["attn"] = L.mla_abstract(cfg) if cfg.mla else L.gqa_abstract(cfg)
        p["norm2"] = _norm_spec(cfg)
        p["moe"] = MOE.moe_abstract(cfg)
    elif kind == "rwkv":
        p["tmix"] = SSM.rwkv_time_mix_abstract(cfg)
        p["norm2"] = _norm_spec(cfg)
        p["cmix"] = SSM.rwkv_channel_mix_abstract(cfg)
    elif kind == "mamba":
        p["mamba"] = SSM.mamba_abstract(cfg)
        p["norm2"] = _norm_spec(cfg)
        p["mlp"] = L.mlp_abstract(cfg)
    elif kind == "mamba_moe":
        p["mamba"] = SSM.mamba_abstract(cfg)
        p["norm2"] = _norm_spec(cfg)
        p["moe"] = MOE.moe_abstract(cfg)
    elif kind == "cross":
        p["attn"] = L.gqa_abstract(cfg)
        p["norm2"] = _norm_spec(cfg)
        p["mlp"] = L.mlp_abstract(cfg)
        p["gate_attn"] = PSpec((1,), (None,), init="zeros")
        p["gate_mlp"] = PSpec((1,), (None,), init="zeros")
    else:
        raise ValueError(kind)
    return p


def block_apply(p, x: jax.Array, cfg: ModelConfig, kind: str,
                positions: jax.Array, *,
                cache: Optional[Dict] = None, cache_index=None,
                vision_states: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    nrm = functools.partial(L.norm, kind=cfg.norm)

    if kind in ("attn", "attn_moe"):
        h = nrm(x, p["norm1"])
        if cfg.mla:
            a, new_cache = L.mla_apply(p["attn"], h, cfg, positions,
                                       cache=cache, cache_index=cache_index)
        else:
            a, new_cache = L.gqa_apply(p["attn"], h, cfg, positions,
                                       cache=cache, cache_index=cache_index)
        if cfg.parallel_block and kind == "attn":
            x = x + a + L.mlp_apply(p["mlp"], h, cfg)
        else:
            x = x + a
            h2 = nrm(x, p["norm2"])
            if kind == "attn_moe":
                mo, aux = MOE.moe_apply(p["moe"], h2, cfg)
                x = x + mo
            else:
                x = x + L.mlp_apply(p["mlp"], h2, cfg)
    elif kind == "rwkv":
        h = nrm(x, p["norm1"])
        a, tstate = SSM.rwkv_time_mix_apply(p["tmix"], h, cfg,
                                            state=cache.get("tmix") if cache else None)
        x = x + a
        h2 = nrm(x, p["norm2"])
        c, cstate = SSM.rwkv_channel_mix_apply(p["cmix"], h2, cfg,
                                               state=cache.get("cmix") if cache else None)
        x = x + c
        new_cache = {"tmix": tstate, "cmix": cstate}
    elif kind in ("mamba", "mamba_moe"):
        h = nrm(x, p["norm1"])
        a, mstate = SSM.mamba_apply(p["mamba"], h, cfg,
                                    state=cache.get("mamba") if cache else None)
        x = x + a
        h2 = nrm(x, p["norm2"])
        if kind == "mamba_moe":
            mo, aux = MOE.moe_apply(p["moe"], h2, cfg)
            x = x + mo
        else:
            x = x + L.mlp_apply(p["mlp"], h2, cfg)
        new_cache = {"mamba": mstate}
    elif kind == "cross":
        h = nrm(x, p["norm1"])
        a, _ = L.gqa_apply(p["attn"], h, cfg, positions,
                           kv_override=(vision_states,), causal=False)
        x = x + jnp.tanh(p["gate_attn"].astype(x.dtype)) * a
        h2 = nrm(x, p["norm2"])
        x = x + jnp.tanh(p["gate_mlp"].astype(x.dtype)) * L.mlp_apply(p["mlp"], h2, cfg)
        new_cache = {}  # vision KV is recomputed (stub frontend, tiny)
    else:
        raise ValueError(kind)
    return x, new_cache, aux


# ------------------------------------------------------------ stack groups --

@dataclasses.dataclass(frozen=True)
class StackGroup:
    name: str
    repeats: int                 # scan length
    kinds: Tuple[str, ...]       # sub-layer kinds within one scan step


def stack_plan(cfg: ModelConfig) -> List[StackGroup]:
    n = cfg.n_layers
    if cfg.ssm and cfg.ssm.kind == "rwkv6":
        return [StackGroup("rwkv", n, ("rwkv",))]
    if cfg.ssm and cfg.ssm.kind == "mamba":       # jamba hybrid
        period = cfg.ssm.attn_period
        kinds = []
        for i in range(period):
            mixer = "attn" if i == cfg.ssm.attn_offset else "mamba"
            use_moe = cfg.moe is not None and (i % cfg.moe.layer_period
                                               == cfg.moe.layer_period - 1)
            if mixer == "attn":
                kinds.append("attn_moe" if use_moe else "attn")
            else:
                kinds.append("mamba_moe" if use_moe else "mamba")
        return [StackGroup("hybrid", n // period, tuple(kinds))]
    if cfg.cross_attn_period:
        per = cfg.cross_attn_period
        kinds = tuple(["attn"] * (per - 1) + ["cross"])
        return [StackGroup("vision", n // per, kinds)]
    if cfg.moe is not None:
        fd = cfg.moe.first_dense
        groups = []
        if fd:
            groups.append(StackGroup("dense", fd, ("attn",)))
        groups.append(StackGroup("moe", n - fd, ("attn_moe",)))
        return groups
    return [StackGroup("dense", n, ("attn",))]


def stack_abstract(cfg: ModelConfig) -> Dict[str, Any]:
    """Stacked (leading repeat axis) parameter tree for all groups."""
    out: Dict[str, Any] = {}
    for g in stack_plan(cfg):
        step = {f"sub{i}_{kind}": block_abstract(cfg, kind)
                for i, kind in enumerate(g.kinds)}
        def add_axis(ps: PSpec) -> PSpec:
            return PSpec((g.repeats,) + ps.shape, (None,) + ps.axes,
                         init=ps.init, scale=ps.scale, dtype=ps.dtype)
        out[g.name] = jax.tree.map(add_axis, step, is_leaf=is_pspec)
    return out


def stack_apply(params: Dict, x: jax.Array, cfg: ModelConfig,
                positions: jax.Array, *,
                caches: Optional[Dict] = None, cache_index=None,
                vision_states: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Dict, jax.Array]:
    """Run all stack groups.  Returns (x, new_caches, total_aux)."""
    total_aux = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}

    for g in stack_plan(cfg):
        gp = params[g.name]
        gc = caches.get(g.name) if caches is not None else None

        def step(carry, xs):
            h, auxc = carry
            p_layer, c_layer = xs
            new_c = {}
            for i, kind in enumerate(g.kinds):
                key = f"sub{i}_{kind}"
                sub_c = c_layer[key] if c_layer is not None else None
                h, nc, aux = block_apply(
                    p_layer[key], h, cfg, kind, positions,
                    cache=sub_c, cache_index=cache_index,
                    vision_states=vision_states)
                new_c[key] = nc if nc is not None else {}
            return (h, auxc + aux), new_c

        if cfg.remat:
            step = jax.checkpoint(step)

        (x, total_aux), nc = jax.lax.scan(step, (x, total_aux), (gp, gc))
        new_caches[g.name] = nc
    return x, new_caches, total_aux


def stack_cache_abstract(cfg: ModelConfig, batch: int, max_len: int
                         ) -> Dict[str, Any]:
    """ShapeDtypeStruct tree for the decode cache (stacked per group)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    h = cfg.d_model // SSM.RWKV_HEAD_DIM if cfg.ssm and cfg.ssm.kind == "rwkv6" else 0
    out: Dict[str, Any] = {}
    for g in stack_plan(cfg):
        step: Dict[str, Any] = {}
        for i, kind in enumerate(g.kinds):
            key = f"sub{i}_{kind}"
            if kind in ("attn", "attn_moe"):
                if cfg.mla:
                    m = cfg.mla
                    step[key] = {
                        "ckv": jax.ShapeDtypeStruct((g.repeats, batch, max_len,
                                                     m.kv_lora_rank), cdt),
                        "kr": jax.ShapeDtypeStruct((g.repeats, batch, max_len,
                                                    m.qk_rope_dim), cdt),
                    }
                else:
                    dh = cfg.head_dim
                    kv_dt = jnp.int8 if cfg.kv_cache_int8_scale else cdt
                    step[key] = {
                        "k": jax.ShapeDtypeStruct((g.repeats, batch, max_len,
                                                   cfg.n_kv_heads, dh), kv_dt),
                        "v": jax.ShapeDtypeStruct((g.repeats, batch, max_len,
                                                   cfg.n_kv_heads, dh), kv_dt),
                    }
                    if cfg.kv_cache_int8_scale:  # per-(token, head) scales
                        for sk in ("ks", "vs"):
                            step[key][sk] = jax.ShapeDtypeStruct(
                                (g.repeats, batch, max_len, cfg.n_kv_heads),
                                jnp.bfloat16)
            elif kind == "rwkv":
                step[key] = {
                    "tmix": {"shift": jax.ShapeDtypeStruct((g.repeats, batch, cfg.d_model), cdt),
                             "wkv": jax.ShapeDtypeStruct((g.repeats, batch, h,
                                                          SSM.RWKV_HEAD_DIM,
                                                          SSM.RWKV_HEAD_DIM), jnp.float32)},
                    "cmix": {"shift": jax.ShapeDtypeStruct((g.repeats, batch, cfg.d_model), cdt)},
                }
            elif kind in ("mamba", "mamba_moe"):
                din = cfg.ssm.expand * cfg.d_model
                step[key] = {"mamba": {
                    "conv": jax.ShapeDtypeStruct((g.repeats, batch,
                                                  cfg.ssm.conv_width - 1, din), cdt),
                    "ssm": jax.ShapeDtypeStruct((g.repeats, batch, din,
                                                 cfg.ssm.d_state), jnp.float32)}}
            else:  # cross
                step[key] = {}
        out[g.name] = step
    return out
