"""Model facade: abstract params, init, train loss, prefill, decode.

One class serves all 10 architectures; behavior is driven entirely by
ModelConfig (stack_plan picks the block pattern).  All public entry points
are pure functions of (params, inputs) and jit/pjit-compatible.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constraint
from repro.models import transformer as T
from repro.models.params import (PSpec, is_pspec, tree_init,
                                 tree_param_count, tree_partition_specs,
                                 tree_shape_structs)


def model_abstract(cfg: ModelConfig) -> Dict[str, Any]:
    p: Dict[str, Any] = {}
    vtp = "tp" if cfg.vocab_size % 16 == 0 else None  # hubert: V=504 replicated
    if not cfg.external_embed:
        p["embed"] = PSpec((cfg.vocab_size, cfg.d_model), (vtp, "fsdp"))
    p["blocks"] = T.stack_abstract(cfg)
    p["final_norm"] = PSpec((cfg.d_model,), (None,), init="ones")
    if not cfg.tie_embeddings:
        p["head"] = PSpec((cfg.d_model, cfg.vocab_size), ("fsdp", vtp))
    if cfg.mtp_depth:
        p["mtp"] = {
            "proj": PSpec((2 * cfg.d_model, cfg.d_model), ("fsdp", None)),
            "block": T.block_abstract(cfg, "attn"),
            "norm": PSpec((cfg.d_model,), (None,), init="ones"),
        }
    return p


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    from repro.models.moe import moe_abstract
    total = tree_param_count(model_abstract(cfg))
    if active_only and cfg.moe is not None:
        m = cfg.moe
        expert_p = tree_param_count(
            {k: v for k, v in moe_abstract(cfg).items()
             if k in ("w1", "w2", "w3")})
        n_moe_layers = (cfg.n_layers - m.first_dense) // m.layer_period
        inactive = expert_p * n_moe_layers * (1 - m.top_k / m.n_experts)
        total -= int(inactive)
    return total


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # ---------------------------------------------------------------- setup --
    def abstract_params(self):
        return model_abstract(self.cfg)

    def partition_specs(self, drop_fsdp: bool = False):
        return tree_partition_specs(self.abstract_params(), drop_fsdp)

    def shape_structs(self):
        return tree_shape_structs(self.abstract_params(), self.cfg.param_dtype)

    def init(self, key: jax.Array):
        return tree_init(self.abstract_params(), key, self.cfg.param_dtype)

    # ------------------------------------------------------------- forward --
    def _embed(self, params, tokens=None, embeds=None) -> jax.Array:
        cdt = jnp.dtype(self.cfg.compute_dtype)
        if embeds is not None:
            return embeds.astype(cdt)
        e = params["embed"].astype(cdt)[tokens]
        return constraint(e, "dp", None, None)

    def _head(self, params, x: jax.Array) -> jax.Array:
        cdt = jnp.dtype(self.cfg.compute_dtype)
        x = T.L.norm(x, params["final_norm"], self.cfg.norm)
        if self.cfg.tie_embeddings:
            w = params["embed"].astype(cdt).T
        else:
            w = params["head"].astype(cdt)
        logits = jnp.einsum("bsd,dv->bsv", x, w)
        vtp = "tp" if self.cfg.vocab_size % 16 == 0 else None
        return constraint(logits.astype(jnp.float32), "dp", None, vtp)

    def forward(self, params, tokens=None, embeds=None, vision_states=None,
                positions=None) -> jax.Array:
        """Full-sequence forward -> fp32 logits (B,S,V)."""
        x = self._embed(params, tokens, embeds)
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x, _, _ = T.stack_apply(params["blocks"], x, self.cfg, positions,
                                vision_states=vision_states)
        return self._head(params, x)

    # ---------------------------------------------------------------- loss --
    def loss(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        x = self._embed(params, batch.get("tokens"), batch.get("embeds"))
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        h, _, aux = T.stack_apply(params["blocks"], x, cfg, positions,
                                  vision_states=batch.get("vision_states"))
        logits = self._head(params, h)
        labels = batch["labels"]
        ce = _xent(logits, labels)
        loss = ce + aux
        if cfg.mtp_depth and "tokens" in batch:
            loss = loss + 0.1 * self._mtp_loss(params, h, batch, positions)
        return loss

    def _mtp_loss(self, params, h, batch, positions) -> jax.Array:
        """DeepSeek-V3 multi-token prediction: one extra depth, predicts t+2."""
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        mtp = params["mtp"]
        tokens, labels = batch["tokens"], batch["labels"]
        # combine hidden state at t with embedding of token t+1
        nxt = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
        e_next = params["embed"].astype(cdt)[nxt]
        z = jnp.concatenate([h, e_next], axis=-1)
        z = jnp.einsum("bsd,dk->bsk", z, mtp["proj"].astype(cdt))
        z, _, _ = T.block_apply(mtp["block"], z, cfg, "attn", positions)
        z = T.L.norm(z, mtp["norm"], cfg.norm)
        w = params["embed"].astype(cdt).T if cfg.tie_embeddings else params["head"].astype(cdt)
        logits2 = jnp.einsum("bsd,dv->bsv", z, w).astype(jnp.float32)
        lab2 = jnp.concatenate([labels[:, 1:], labels[:, -1:]], axis=1)
        return _xent(logits2, lab2)

    # -------------------------------------------------------------- serving --
    def prefill(self, params, tokens=None, embeds=None, vision_states=None,
                max_len: Optional[int] = None
                ) -> Tuple[jax.Array, Dict[str, Any]]:
        """Run the prompt; returns (last-position logits, decode cache)."""
        cfg = self.cfg
        x = self._embed(params, tokens, embeds)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        h, caches, _ = T.stack_apply(params["blocks"], x, cfg, positions,
                                     caches=None, vision_states=vision_states)
        logits = self._head(params, h[:, -1:, :])
        if max_len is not None and max_len > s:
            caches = _pad_caches(caches, max_len, seq_axis=2)
        return logits, caches

    def init_cache_structs(self, batch: int, max_len: int):
        return T.stack_cache_abstract(self.cfg, batch, max_len)

    def decode_step(self, params, cache, index: jax.Array,
                    tokens: jax.Array, vision_states=None
                    ) -> Tuple[jax.Array, Dict[str, Any]]:
        """One-token decode: tokens (B,1) at position ``index`` (scalar)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        b = x.shape[0]
        positions = jnp.broadcast_to(index[None, None], (b, 1)).astype(jnp.int32)
        h, new_cache, _ = T.stack_apply(params["blocks"], x, cfg, positions,
                                        caches=cache, cache_index=index,
                                        vision_states=vision_states)
        return self._head(params, h), new_cache


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


_SEQ_CACHE_KEYS = ("k", "v", "ckv", "kr", "ks", "vs")  # caches with a seq axis


def _pad_caches(caches, max_len: int, seq_axis: int):
    def pad(path, x):
        leaf_key = path[-1].key if hasattr(path[-1], "key") else None
        if leaf_key in _SEQ_CACHE_KEYS and x.shape[seq_axis] < max_len:
            pads = [(0, 0)] * x.ndim
            pads[seq_axis] = (0, max_len - x.shape[seq_axis])
            return jnp.pad(x, pads)
        return x
    return jax.tree_util.tree_map_with_path(pad, caches)
