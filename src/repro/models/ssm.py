"""Attention-free sequence mixers: RWKV6 (Finch) and Mamba (selective SSM).

Both are formulated as a single ``lax.scan`` over time for training/prefill
(rolled HLO: compile-time stays flat in sequence length, memory O(state)),
and as an O(1)-state single-step update for decode — this is what makes the
``long_500k`` cells feasible where quadratic attention is skipped.

RWKV6 implements the paper-defining *data-dependent decay*: the per-channel
decay ``w_t = exp(-exp(w0 + lora(x_t-shift)))`` varies per token, plus the
ddlerp token-shift mixers of Finch (arXiv:2404.05892).

Mamba implements the selective SSM (S4D discretization, input-dependent
Delta/B/C) with the depthwise causal conv, as used by Jamba's Mamba layers.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constraint
from repro.models.params import PSpec
from repro.models.layers import rmsnorm

RWKV_HEAD_DIM = 64
DDLERP_RANK = 32
DECAY_RANK = 64


# ------------------------------------------------------------------- RWKV6 --

def rwkv_time_mix_abstract(cfg: ModelConfig) -> Dict[str, PSpec]:
    d = cfg.d_model
    h = d // RWKV_HEAD_DIM
    tp = "tp" if h % 16 == 0 else None
    return {
        "mu_x": PSpec((d,), (None,), init="zeros"),
        "mu": PSpec((5, d), (None, None), init="zeros"),       # w,k,v,r,g
        "ddlerp_a": PSpec((d, 5 * DDLERP_RANK), ("fsdp", None)),
        "ddlerp_b": PSpec((5, DDLERP_RANK, d), (None, None, None), init="zeros"),
        "w0": PSpec((d,), (None,), init="zeros"),
        "decay_a": PSpec((d, DECAY_RANK), ("fsdp", None)),
        "decay_b": PSpec((DECAY_RANK, d), (None, None), init="zeros"),
        "u": PSpec((d,), (None,), init="zeros"),               # bonus
        "wr": PSpec((d, d), ("fsdp", tp)),
        "wk": PSpec((d, d), ("fsdp", tp)),
        "wv": PSpec((d, d), ("fsdp", tp)),
        "wg": PSpec((d, d), ("fsdp", tp)),
        "ln_w": PSpec((d,), (None,), init="ones"),             # per-head groupnorm
        "wo": PSpec((d, d), (tp, "fsdp")),
    }


def _rwkv_ddlerp(p, x, sx, cdt):
    """Finch data-dependent lerp: five mixed inputs (w,k,v,r,g)."""
    dx = sx - x
    xxx = x + dx * p["mu_x"].astype(cdt)
    a = jnp.tanh(jnp.einsum("btd,dr->btr", xxx, p["ddlerp_a"].astype(cdt)))
    a = a.reshape(*a.shape[:-1], 5, DDLERP_RANK)
    mix = p["mu"].astype(cdt) + jnp.einsum("btir,ird->btid", a, p["ddlerp_b"].astype(cdt))
    return [x + dx * mix[..., i, :] for i in range(5)]


def rwkv_time_mix_apply(p, x: jax.Array, cfg: ModelConfig,
                        state: Optional[Dict] = None
                        ) -> Tuple[jax.Array, Dict]:
    """x: (B,S,D). state (decode): {'shift': (B,D), 'wkv': (B,H,K,V)}."""
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s, d = x.shape
    h = d // RWKV_HEAD_DIM
    if state is None:
        sx = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
        wkv0 = jnp.zeros((b, h, RWKV_HEAD_DIM, RWKV_HEAD_DIM), jnp.float32)
    else:
        sx = state["shift"][:, None, :].astype(cdt)
        wkv0 = state["wkv"]
    xw, xk, xv, xr, xg = _rwkv_ddlerp(p, x, sx, cdt)

    # data-dependent decay (the Finch signature)
    wlog = p["w0"].astype(jnp.float32) + jnp.einsum(
        "btd,dr,rk->btk", xw.astype(jnp.float32),
        p["decay_a"].astype(jnp.float32), p["decay_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(jnp.clip(wlog, -8.0, 4.0)))           # (B,S,D) in (0,1)

    r = jnp.einsum("btd,de->bte", xr, p["wr"].astype(cdt))
    k = jnp.einsum("btd,de->bte", xk, p["wk"].astype(cdt))
    v = jnp.einsum("btd,de->bte", xv, p["wv"].astype(cdt))
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, p["wg"].astype(cdt)))

    hd = RWKV_HEAD_DIM
    rh = r.reshape(b, s, h, hd).astype(jnp.float32)
    kh = k.reshape(b, s, h, hd).astype(jnp.float32)
    vh = v.reshape(b, s, h, hd).astype(jnp.float32)
    wh = w.reshape(b, s, h, hd)
    u = p["u"].astype(jnp.float32).reshape(h, hd)

    def step(S, inp):
        rt, kt, vt, wt = inp                   # (B,H,K) / (B,H,V) / (B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,K,V)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S_new = wt[..., :, None] * S + kv
        return S_new, out

    xs = (jnp.moveaxis(rh, 1, 0), jnp.moveaxis(kh, 1, 0),
          jnp.moveaxis(vh, 1, 0), jnp.moveaxis(wh, 1, 0))
    # two-level scan: outer over chunks with rematerialized inner scans.
    # A flat scan would checkpoint the (B,H,K,V) state at EVERY step for the
    # backward pass (4096 steps x 16KB/head = GBs per layer); chunked remat
    # stores only chunk-boundary states and recomputes inside (64x less).
    chunk = 64
    if s % chunk == 0 and s > chunk:
        nch = s // chunk
        xs_c = jax.tree.map(
            lambda a: a.reshape(nch, chunk, *a.shape[1:]), xs)

        @jax.checkpoint
        def chunk_step(S, inp_c):
            return jax.lax.scan(step, S, inp_c)

        S_fin, outs = jax.lax.scan(chunk_step, wkv0, xs_c)
        outs = outs.reshape(s, b, h, hd)
    else:
        S_fin, outs = jax.lax.scan(step, wkv0, xs)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hd)       # (B,S,H,V)

    # per-head groupnorm
    mean = jnp.mean(out, axis=-1, keepdims=True)
    var = jnp.var(out, axis=-1, keepdims=True)
    out = (out - mean) * jax.lax.rsqrt(var + 64e-5)
    out = out.reshape(b, s, d) * p["ln_w"].astype(jnp.float32)
    out = (out.astype(cdt) * g)
    y = jnp.einsum("btd,de->bte", out, p["wo"].astype(cdt))
    new_state = {"shift": x[:, -1, :], "wkv": S_fin}
    return constraint(y, "dp", None, None), new_state


def rwkv_channel_mix_abstract(cfg: ModelConfig) -> Dict[str, PSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": PSpec((d,), (None,), init="zeros"),
        "mu_r": PSpec((d,), (None,), init="zeros"),
        "wk": PSpec((d, f), ("fsdp", "tp")),
        "wv": PSpec((f, d), ("tp", "fsdp")),
        "wr": PSpec((d, d), ("fsdp", None)),
    }


def rwkv_channel_mix_apply(p, x: jax.Array, cfg: ModelConfig,
                           state: Optional[Dict] = None
                           ) -> Tuple[jax.Array, Dict]:
    cdt = jnp.dtype(cfg.compute_dtype)
    if state is None:
        sx = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    else:
        sx = state["shift"][:, None, :].astype(cdt)
    xk = x + (sx - x) * p["mu_k"].astype(cdt)
    xr = x + (sx - x) * p["mu_r"].astype(cdt)
    k = jnp.einsum("btd,df->btf", xk, p["wk"].astype(cdt))
    k = jnp.square(jax.nn.relu(k))
    v = jnp.einsum("btf,fd->btd", k, p["wv"].astype(cdt))
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr"].astype(cdt)))
    return r * v, {"shift": x[:, -1, :]}


# ------------------------------------------------------------------- Mamba --

def mamba_abstract(cfg: ModelConfig) -> Dict[str, PSpec]:
    d = cfg.d_model
    ssm = cfg.ssm
    din = ssm.expand * d
    dtr = ssm.dt_rank or d // 16
    ds = ssm.d_state
    return {
        "in_proj": PSpec((d, 2 * din), ("fsdp", "tp")),
        "conv_w": PSpec((ssm.conv_width, din), (None, "tp")),
        "conv_b": PSpec((din,), ("tp",), init="zeros"),
        "w_dt_down": PSpec((din, dtr), ("tp", None)),
        "w_dt_up": PSpec((dtr, din), (None, "tp")),
        "dt_bias": PSpec((din,), ("tp",), init="zeros"),
        "w_b": PSpec((din, ds), ("tp", None)),
        "w_c": PSpec((din, ds), ("tp", None)),
        "a_log": PSpec((din, ds), ("tp", None), init="zeros"),
        "d_skip": PSpec((din,), ("tp",), init="ones"),
        "out_proj": PSpec((din, d), ("tp", "fsdp")),
    }


def mamba_apply(p, x: jax.Array, cfg: ModelConfig,
                state: Optional[Dict] = None) -> Tuple[jax.Array, Dict]:
    """x: (B,S,D).  state (decode): {'conv': (B,W-1,din), 'ssm': (B,din,ds)}."""
    cdt = jnp.dtype(cfg.compute_dtype)
    ssm = cfg.ssm
    b, s, d = x.shape
    din = ssm.expand * d
    ds = ssm.d_state
    wconv = ssm.conv_width

    xz = jnp.einsum("btd,de->bte", x, p["in_proj"].astype(cdt))
    xin, z = xz[..., :din], xz[..., din:]

    # depthwise causal conv
    if state is None:
        pad = jnp.zeros((b, wconv - 1, din), cdt)
    else:
        pad = state["conv"].astype(cdt)
    xpad = jnp.concatenate([pad, xin], axis=1)                 # (B, S+W-1, din)
    conv = sum(xpad[:, i:i + s, :] * p["conv_w"][i].astype(cdt)
               for i in range(wconv)) + p["conv_b"].astype(cdt)
    xc = jax.nn.silu(conv)

    dt = jnp.einsum("bte,er,rf->btf", xc, p["w_dt_down"].astype(cdt),
                    p["w_dt_up"].astype(cdt)) + p["dt_bias"].astype(cdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32))               # (B,S,din)
    Bm = jnp.einsum("bte,es->bts", xc, p["w_b"].astype(cdt)).astype(jnp.float32)
    Cm = jnp.einsum("bte,es->bts", xc, p["w_c"].astype(cdt)).astype(jnp.float32)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))               # (din, ds)

    h0 = state["ssm"] if state is not None else jnp.zeros((b, din, ds), jnp.float32)
    xc32 = xc.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                                  # (B,din),(B,din),(B,ds),(B,ds)
        da = jnp.exp(dtt[..., None] * A[None])                 # (B,din,ds)
        h_new = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bds,bs->bd", h_new, ct)
        return h_new, y

    xs = (jnp.moveaxis(xc32, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    # chunked-remat scan (see rwkv wkv): store only chunk-boundary SSM states
    chunk = 64
    if s % chunk == 0 and s > chunk:
        nch = s // chunk
        xs_c = jax.tree.map(lambda a: a.reshape(nch, chunk, *a.shape[1:]), xs)

        @jax.checkpoint
        def chunk_step(hc, inp_c):
            return jax.lax.scan(step, hc, inp_c)

        h_fin, ys = jax.lax.scan(chunk_step, h0, xs_c)
        ys = ys.reshape(s, *ys.shape[2:])
    else:
        h_fin, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + xc32 * p["d_skip"].astype(jnp.float32)
    y = (y.astype(cdt) * jax.nn.silu(z))
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(cdt))
    new_state = {"conv": xpad[:, -(wconv - 1):, :], "ssm": h_fin}
    return constraint(out, "dp", None, None), new_state
