"""Shared transformer layers: norms, RoPE, flash-style chunked attention
(causal / bidirectional / sliding-window / cross), GQA, MLA, gated MLP.

Attention is blockwise (running log-sum-exp over KV chunks) so >=32k-token
sequences never materialize an (S x S) score matrix.  Causal attention
iterates only the chunks at-or-below the diagonal (a static python loop over
query chunks with exactly the needed KV scan length), so compiled FLOPs track
the `S(S+1)/2` triangle rather than the full square.  SWA additionally
restricts each query chunk's KV range to its window -> O(S*w) compute.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MLAConfig
from repro.distributed.sharding import constraint
from repro.models.params import PSpec

# ------------------------------------------------------------------- norms --

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x - jnp.mean(x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def norm(x: jax.Array, w: jax.Array, kind: str) -> jax.Array:
    return rmsnorm(x, w) if kind == "rmsnorm" else layernorm(x, w)


# -------------------------------------------------------------------- RoPE --

def rope_freqs(dh: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, dh, 2, dtype=np.float64) / dh))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, dh) rotated pairwise; positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)
    # angles: (..., S, 1, dh/2)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs[None, None, :]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# -------------------------------------------------- blockwise attention -----

def _attn_block(q, k, v, scale, mask):
    """One (q_chunk x kv_chunk) block: returns (scores_max, exp_sum, pv)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    return m, l, pv


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    q_offset: int = 0,
                    q_chunk: int = 1024, kv_chunk: int = 1024) -> jax.Array:
    """Blockwise attention.

    q: (B, Sq, H, dh); k, v: (B, Skv, Hkv, dh) with H % Hkv == 0 (GQA).
    ``q_offset`` is the absolute position of q[0] relative to k[0] (used by
    chunked prefill; 0 for self-attention).  window > 0 = sliding window.
    Returns (B, Sq, H, dh).
    """
    b, sq, h, dh = q.shape
    _, skv, hkv, _ = k.shape
    dv = v.shape[-1]
    g = h // hkv
    scale = dh ** -0.5
    qg = q.reshape(b, sq, hkv, g, dh)

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    # pad KV to a chunk multiple: dynamic_slice clamps out-of-range starts,
    # which would silently misalign kv_pos on the last chunk otherwise
    skv_pad = ((skv + kv_chunk - 1) // kv_chunk) * kv_chunk
    if skv_pad != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0)))
    n_q = (sq + q_chunk - 1) // q_chunk
    outs = []
    for qi in range(n_q):
        q0 = qi * q_chunk
        qc = min(q_chunk, sq - q0)
        qblk = jax.lax.dynamic_slice_in_dim(qg, q0, qc, axis=1)
        q_pos_hi = q_offset + q0 + qc - 1  # last query position in block
        # KV range this block can see
        if causal:
            kv_hi = min(q_pos_hi + 1, skv)
        else:
            kv_hi = skv
        kv_lo = 0
        if window > 0:
            kv_lo = max(0, q_offset + q0 - window + 1)
        # align to chunks (static)
        c_lo = kv_lo // kv_chunk
        c_hi = (kv_hi + kv_chunk - 1) // kv_chunk
        n_kv = max(c_hi - c_lo, 1)

        q_pos = q_offset + q0 + jnp.arange(qc)

        def body(carry, ci):
            m_run, l_run, acc = carry
            k0 = (c_lo + ci) * kv_chunk
            kblk = jax.lax.dynamic_slice_in_dim(k, k0, kv_chunk, axis=1)
            vblk = jax.lax.dynamic_slice_in_dim(v, k0, kv_chunk, axis=1)
            kv_pos = k0 + jnp.arange(kv_chunk)
            mask = jnp.ones((qc, kv_chunk), bool)
            if causal:
                mask &= q_pos[:, None] >= kv_pos[None, :]
            if window > 0:
                mask &= q_pos[:, None] - kv_pos[None, :] < window
            mask &= (kv_pos < skv)[None, :]
            m, l, pv = _attn_block(qblk, kblk, vblk, scale,
                                   mask[None, None, None, :, :])
            m_new = jnp.maximum(m_run, m)
            corr_old = jnp.exp(m_run - m_new)
            corr_new = jnp.exp(m - m_new)
            l_new = l_run * corr_old + l * corr_new
            # shapes -- m,l: (b,hkv,g,qc); acc/pv: (b,qc,hkv,g,dv)
            corr_old_b = jnp.transpose(corr_old, (0, 3, 1, 2))[..., None]
            corr_new_b = jnp.transpose(corr_new, (0, 3, 1, 2))[..., None]
            acc_new = acc * corr_old_b + pv * corr_new_b
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, qc, hkv, g, dv), jnp.float32)
        (m_f, l_f, acc_f), _ = jax.lax.scan(body, (m0, l0, a0),
                                            jnp.arange(n_kv))
        l_b = jnp.transpose(l_f, (0, 3, 1, 2))[..., None]
        outs.append((acc_f / jnp.maximum(l_b, 1e-30)).astype(q.dtype))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out.reshape(b, sq, h, dv)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *, window: int = 0,
                     kv_chunk: int = 2048, scale: Optional[float] = None,
                     return_lse: bool = False, kv_scales=None):
    """Single-position decode: q (B,1,H,dh) vs cache (B,L,Hkv,dh).

    ``cache_len`` (scalar int32) = number of valid cache positions.  SWA only
    attends to the last ``window`` positions.  Memory-bound by design: one
    pass over the cache with a running LSE.  ``return_lse`` exposes the raw
    (acc, m, l) triple for cross-shard combination (flash-decoding).
    """
    b, _, h, dh = q.shape
    _, L, hkv, _ = k_cache.shape
    dv = v_cache.shape[-1]
    g = h // hkv
    scale = dh ** -0.5 if scale is None else scale
    qg = q.reshape(b, 1, hkv, g, dh)
    kv_chunk = min(kv_chunk, L)
    n_kv = (L + kv_chunk - 1) // kv_chunk
    L_pad = n_kv * kv_chunk
    if L_pad != L:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, L_pad - L), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, L_pad - L), (0, 0), (0, 0)))
        if kv_scales is not None:
            kv_scales = tuple(jnp.pad(s, ((0, 0), (0, L_pad - L), (0, 0)))
                              for s in kv_scales)

    def body(carry, ci):
        m_run, l_run, acc = carry
        k0 = ci * kv_chunk
        kblk = jax.lax.dynamic_slice_in_dim(k_cache, k0, kv_chunk, axis=1)
        vblk = jax.lax.dynamic_slice_in_dim(v_cache, k0, kv_chunk, axis=1)
        if kv_scales is not None:  # int8 cache: dequantize per chunk
            ksb = jax.lax.dynamic_slice_in_dim(kv_scales[0], k0, kv_chunk, axis=1)
            vsb = jax.lax.dynamic_slice_in_dim(kv_scales[1], k0, kv_chunk, axis=1)
            kblk = kv_dequantize(kblk, ksb, q.dtype)
            vblk = kv_dequantize(vblk, vsb, q.dtype)
        kv_pos = k0 + jnp.arange(kv_chunk)
        mask = kv_pos < cache_len
        if window > 0:
            mask &= kv_pos >= cache_len - window
        m, l, pv = _attn_block(qg, kblk, vblk, scale,
                               mask[None, None, None, None, :])
        m_new = jnp.maximum(m_run, m)
        c_o = jnp.exp(m_run - m_new)
        c_n = jnp.exp(m - m_new)
        l_new = l_run * c_o + l * c_n
        c_o_b = jnp.transpose(c_o, (0, 3, 1, 2))[..., None]
        c_n_b = jnp.transpose(c_n, (0, 3, 1, 2))[..., None]
        return (m_new, l_new, acc * c_o_b + pv * c_n_b), None

    m0 = jnp.full((b, hkv, g, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, 1), jnp.float32)
    a0 = jnp.zeros((b, 1, hkv, g, dv), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(n_kv))
    if return_lse:
        return acc, m_f, l_f
    l_b = jnp.transpose(l_f, (0, 3, 1, 2))[..., None]
    return (acc / jnp.maximum(l_b, 1e-30)).astype(q.dtype).reshape(b, 1, h, dv)


# ----------------------------------------------------------- GQA attention --

def gqa_abstract(cfg: ModelConfig) -> Dict[str, PSpec]:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    tp_heads = "tp" if h % 16 == 0 else None  # uneven head counts stay local
    p: Dict[str, PSpec] = {
        "wq": PSpec((d, h, dh), ("fsdp", tp_heads, None)),
        "wk": PSpec((d, hkv, dh), ("fsdp", None, None)),
        "wv": PSpec((d, hkv, dh), ("fsdp", None, None)),
        "wo": PSpec((h, dh, d), (tp_heads, None, "fsdp")),
    }
    if cfg.qkv_bias:
        p["bq"] = PSpec((h, dh), (tp_heads, None), init="zeros")
        p["bk"] = PSpec((hkv, dh), (None, None), init="zeros")
        p["bv"] = PSpec((hkv, dh), (None, None), init="zeros")
    return p


def gqa_apply(p, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
              *, cache: Optional[Dict] = None, cache_index=None,
              kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
              causal: bool = True) -> Tuple[jax.Array, Optional[Dict]]:
    """GQA attention.  If `cache` is given, runs single-token decode and
    returns the updated cache.  `kv_override` supplies external K/V source
    states (cross-attention)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cdt))
    if "bq" in p:
        q = q + p["bq"].astype(cdt)
    if kv_override is None:
        src = x
    else:
        src = kv_override[0]
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(cdt))
    if "bk" in p:
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    if kv_override is None:  # self-attention: rope
        q = apply_rope(q, positions, cfg.rope_theta)
        src_pos = positions if cache is None else positions
        k = apply_rope(k, src_pos, cfg.rope_theta)
    q = constraint(q, "dp", None, "tp" if cfg.n_heads % 16 == 0 else None, None)

    if cache is not None:
        # single-token decode against the cache
        L = cache["k"].shape[1]
        int8_cache = bool(cfg.kv_cache_int8_scale)
        ks = vs = None
        if int8_cache:
            k, ks_new = kv_quantize(k)
            v, vs_new = kv_quantize(v)
        if cfg.seq_shard_decode and not (cfg.attn_window and L <= cfg.attn_window):
            if int8_cache:
                out, k_cache, v_cache, ks, vs = seqshard_decode_gqa_int8(
                    q, cache["k"], cache["v"], cache["ks"], cache["vs"],
                    k, v, ks_new, vs_new, cache_index, cfg.decode_batch_axes)
            else:
                out, k_cache, v_cache = seqshard_decode_gqa(
                    q, cache["k"], cache["v"], k, v, cache_index,
                    cfg.decode_batch_axes)
        elif cfg.attn_window and L <= cfg.attn_window:
            # rolling window cache: slot = index mod window; every resident
            # entry is in-window by construction (keys carry absolute RoPE,
            # softmax is order-invariant)
            slot = jnp.mod(cache_index, L)
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
            scales = None
            if int8_cache:
                ks = jax.lax.dynamic_update_slice_in_dim(cache["ks"], ks_new, slot, axis=1)
                vs = jax.lax.dynamic_update_slice_in_dim(cache["vs"], vs_new, slot, axis=1)
                scales = (ks, vs)
            clen = jnp.minimum(cache_index + 1, L)
            out = decode_attention(q, k_cache, v_cache, clen, window=0,
                                   kv_scales=scales)
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_index, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_index, axis=1)
            scales = None
            if int8_cache:
                ks = jax.lax.dynamic_update_slice_in_dim(cache["ks"], ks_new, cache_index, axis=1)
                vs = jax.lax.dynamic_update_slice_in_dim(cache["vs"], vs_new, cache_index, axis=1)
                scales = (ks, vs)
            out = decode_attention(q, k_cache, v_cache, cache_index + 1,
                                   window=cfg.attn_window, kv_scales=scales)
        new_cache = {"k": k_cache, "v": v_cache}
        if int8_cache:
            new_cache["ks"], new_cache["vs"] = ks, vs
    else:
        out = flash_attention(q, k, v, causal=causal and kv_override is None,
                              window=cfg.attn_window)
        if cfg.kv_cache_int8_scale:  # prefill fills an int8 cache
            kq, kss = kv_quantize(k)
            vq, vss = kv_quantize(v)
            new_cache = {"k": kq, "v": vq, "ks": kss, "vs": vss}
        else:
            new_cache = {"k": k, "v": v}  # prefill: return built cache
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cdt))
    return constraint(y, "dp", None, None), new_cache


# --------------------------------------------------------------------- MLA --

def mla_abstract(cfg: ModelConfig) -> Dict[str, PSpec]:
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim
    return {
        "w_dq": PSpec((d, m.q_lora_rank), ("fsdp", None)),
        "q_norm": PSpec((m.q_lora_rank,), (None,), init="ones"),
        "w_uq": PSpec((m.q_lora_rank, h, qk + m.qk_rope_dim), (None, "tp", None)),
        "w_dkv": PSpec((d, m.kv_lora_rank + m.qk_rope_dim), ("fsdp", None)),
        "kv_norm": PSpec((m.kv_lora_rank,), (None,), init="ones"),
        "w_uk": PSpec((m.kv_lora_rank, h, qk), (None, "tp", None)),
        "w_uv": PSpec((m.kv_lora_rank, h, m.v_head_dim), (None, "tp", None)),
        "wo": PSpec((h, m.v_head_dim, d), ("tp", None, "fsdp")),
    }


def mla_apply(p, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
              *, cache: Optional[Dict] = None, cache_index=None
              ) -> Tuple[jax.Array, Optional[Dict]]:
    """Multi-head Latent Attention.

    Prefill/train: expanded form (materialize per-head K/V from the latent).
    Decode: absorbed form — the cache stores only (c_kv, k_rope), queries are
    projected into the latent space, giving the MQA-like memory profile that
    makes MLA's 32k cache 8-9x smaller than GQA's."""
    m: MLAConfig = cfg.mla
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = x.shape
    h, qk, qr = cfg.n_heads, m.qk_nope_dim, m.qk_rope_dim

    cq = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(cdt)), p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"].astype(cdt))
    q_nope, q_rope = q[..., :qk], q[..., qk:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(cdt))
    c_kv = rmsnorm(dkv[..., :m.kv_lora_rank], p["kv_norm"])
    k_rope = apply_rope(dkv[..., None, m.kv_lora_rank:], positions, cfg.rope_theta)

    scale = (qk + qr) ** -0.5
    if cache is not None:
        # absorbed decode: fold W_uk into q and attend in the latent space —
        # equivalent to MQA with one (kv_lora+rope)-dim kv head, so it reuses
        # the chunked/flash decode path (and seq-sharding) directly.
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"].astype(cdt))
        q_abs = jnp.concatenate([q_lat, q_rope], axis=-1)      # (B,1,H,r+qr)
        k_new = jnp.concatenate([c_kv, k_rope[:, :, 0, :]],
                                axis=-1)[:, :, None, :]        # (B,1,1,r+qr)
        v_new = c_kv[:, :, None, :]                            # (B,1,1,r)
        k_cache_full = jnp.concatenate([cache["ckv"], cache["kr"]],
                                       axis=-1)[:, :, None, :]
        v_cache_full = cache["ckv"][:, :, None, :]
        if cfg.seq_shard_decode:
            o_lat, k_cache_full, v_cache_full = seqshard_decode_gqa(
                q_abs, k_cache_full, v_cache_full, k_new, v_new, cache_index,
                cfg.decode_batch_axes, scale=scale)
        else:
            k_cache_full = jax.lax.dynamic_update_slice_in_dim(
                k_cache_full, k_new, cache_index, axis=1)
            v_cache_full = jax.lax.dynamic_update_slice_in_dim(
                v_cache_full, v_new, cache_index, axis=1)
            o_lat = decode_attention(q_abs, k_cache_full, v_cache_full,
                                     cache_index + 1, scale=scale)
        out = jnp.einsum("bshr,rhv->bshv", o_lat.astype(cdt),
                         p["w_uv"].astype(cdt))
        new_cache = {"ckv": v_cache_full[:, :, 0, :],
                     "kr": k_cache_full[:, :, 0, m.kv_lora_rank:]}
    else:
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"].astype(cdt))
        v = jnp.einsum("bsr,rhv->bshv", c_kv, p["w_uv"].astype(cdt))
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope, (b, s, h, qr))], axis=-1)
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = flash_attention(qfull, k, v, causal=True)
        # flash_attention assumes q/k same dh for v; v dim differs -> handled:
        new_cache = {"ckv": c_kv, "kr": k_rope[:, :, 0, :]}
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(cdt))
    return constraint(y, "dp", None, None), new_cache


# --------------------------------------------------------------------- MLP --

def mlp_abstract(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, PSpec]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w1": PSpec((d, f), ("fsdp", "tp")),   # gate
        "w3": PSpec((d, f), ("fsdp", "tp")),   # up
        "w2": PSpec((f, d), ("tp", "fsdp")),   # down
    }


def mlp_apply(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    cdt = jnp.dtype(cfg.compute_dtype)
    g = jnp.einsum("bsd,df->bsf", x, p["w1"].astype(cdt))
    u = jnp.einsum("bsd,df->bsf", x, p["w3"].astype(cdt))
    hcat = jax.nn.silu(g) * u
    hcat = constraint(hcat, "dp", None, "tp")
    y = jnp.einsum("bsf,fd->bsd", hcat, p["w2"].astype(cdt))
    return constraint(y, "dp", None, None)


# ------------------------------------------------------- int8 KV cache -----

def kv_quantize(x: jax.Array):
    """HP-MDR-style per-(token, head) exponent alignment: int8 mantissa +
    one bf16 scale per head-vector (1/dh overhead).  Returns (q, scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale * 127.0), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)[..., 0]


def kv_dequantize(q: jax.Array, scales: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32)
            * (scales.astype(jnp.float32)[..., None] / 127.0)).astype(dtype)


# ------------------------------------------------ seq-sharded flash decode --

def _lse_combine(acc, m, l, axis_name: str):
    """Flash-decoding cross-shard combine of (acc, m, l) partials."""
    m_g = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_g)                       # (b,hkv,g,1)
    l_g = jax.lax.psum(l * corr, axis_name)
    corr_b = jnp.transpose(corr, (0, 3, 1, 2))[..., None]
    acc_g = jax.lax.psum(acc * corr_b, axis_name)
    return acc_g, l_g


def _masked_update(cache_local, new, index, lo, L_local):
    """Update position ``index`` if it falls in this shard's [lo, lo+L)."""
    off = index - lo
    in_range = (off >= 0) & (off < L_local)
    upd = jax.lax.dynamic_update_slice_in_dim(
        cache_local, new.astype(cache_local.dtype),
        jnp.clip(off, 0, L_local - 1), axis=1)
    return jnp.where(in_range, upd, cache_local)


def seqshard_decode_gqa(q, k_cache, v_cache, k_new, v_new, index,
                        batch_axes, *, scale=None):
    """Flash-decoding with the KV cache sharded over 'model' on the L axis.

    All heads are computed on every model shard (decode is memory-bound; the
    cache READ is the cost and it is perfectly sharded — wire traffic is one
    (B,1,H,dv)+LSE psum per layer instead of a 1/16-replicated cache)."""
    from repro.distributed.sharding import (get_current_mesh, shard_map,
                                             spec as shspec)
    from jax.sharding import PartitionSpec as P
    mesh = get_current_mesh()
    b_ax = tuple(batch_axes) if batch_axes else None
    cache_spec = shspec(b_ax, "model", None, None)
    q_spec = shspec(b_ax, None, None, None)

    def body(qs, kc, vc, kn, vn, idx):
        L_local = kc.shape[1]
        lo = jax.lax.axis_index("model") * L_local
        kc = _masked_update(kc, kn, idx, lo, L_local)
        vc = _masked_update(vc, vn, idx, lo, L_local)
        clen_local = jnp.clip(idx + 1 - lo, 0, L_local)
        acc, m, l = decode_attention(qs, kc, vc, clen_local, window=0,
                                     scale=scale, return_lse=True)
        acc_g, l_g = _lse_combine(acc, m, l, "model")
        l_b = jnp.transpose(l_g, (0, 3, 1, 2))[..., None]
        out = (acc_g / jnp.maximum(l_b, 1e-30)).astype(qs.dtype)
        return out.reshape(qs.shape[0], 1, qs.shape[2], vc.shape[-1]), kc, vc

    smap = shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, cache_spec, cache_spec, q_spec, q_spec, P()),
        out_specs=(q_spec, cache_spec, cache_spec),
        check_vma=False)
    return smap(q, k_cache, v_cache, k_new, v_new, index)


def seqshard_decode_gqa_int8(q, k_cache, v_cache, ks_cache, vs_cache,
                             k_new, v_new, ks_new, vs_new, index, batch_axes,
                             *, scale=None):
    """Flash-decoding over an int8, per-(token,head)-aligned KV cache
    (HP-MDR alignment on serving state): cache reads are half the bytes."""
    from repro.distributed.sharding import (get_current_mesh, shard_map,
                                             spec as shspec)
    from jax.sharding import PartitionSpec as P
    mesh = get_current_mesh()
    b_ax = tuple(batch_axes) if batch_axes else None
    cache_spec = shspec(b_ax, "model", None, None)
    scale_spec = shspec(b_ax, "model", None)
    q_spec = shspec(b_ax, None, None, None)
    new_scale_spec = shspec(b_ax, None, None)

    def body(qs, kc, vc, ksc, vsc, kn, vn, ksn, vsn, idx):
        L_local = kc.shape[1]
        lo = jax.lax.axis_index("model") * L_local
        kc = _masked_update(kc, kn, idx, lo, L_local)
        vc = _masked_update(vc, vn, idx, lo, L_local)
        ksc = _masked_update(ksc, ksn, idx, lo, L_local)
        vsc = _masked_update(vsc, vsn, idx, lo, L_local)
        clen_local = jnp.clip(idx + 1 - lo, 0, L_local)
        acc, m, l = decode_attention(qs, kc, vc, clen_local, window=0,
                                     scale=scale, return_lse=True,
                                     kv_scales=(ksc, vsc))
        acc_g, l_g = _lse_combine(acc, m, l, "model")
        l_b = jnp.transpose(l_g, (0, 3, 1, 2))[..., None]
        out = (acc_g / jnp.maximum(l_b, 1e-30)).astype(qs.dtype)
        return (out.reshape(qs.shape[0], 1, qs.shape[2], vc.shape[-1]),
                kc, vc, ksc, vsc)

    smap = shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, cache_spec, cache_spec, scale_spec, scale_spec,
                  q_spec, q_spec, new_scale_spec, new_scale_spec, P()),
        out_specs=(q_spec, cache_spec, cache_spec, scale_spec, scale_spec),
        check_vma=False)
    return smap(q, k_cache, v_cache, ks_cache, vs_cache, k_new, v_new,
                ks_new, vs_new, index)
