"""Mixture-of-Experts FFN: token-choice top-k routing, capacity-based
sort-and-scatter dispatch, expert parallelism over the 'model' mesh axis.

Design (DESIGN.md §5 "EP-as-TP"): expert weights are sharded on the expert
axis over 'model'.  Dispatch is a pure-jnp sort/scatter into an (E, C, D)
capacity buffer (constrained to the same expert sharding); the expert matmuls
are then fully local to each model shard; the combine scatter-add brings
results back to token order.  No ragged all-to-all is required — the
collective footprint matches a Megatron FFN (gather of the (E,C,D) blocks),
which the dry-run HLO makes visible and §Perf iterates on.

Tokens overflowing an expert's capacity ``C = ceil(T*k/E * cap_factor)`` are
dropped (pass through via the residual), the standard TPU MoE strategy.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.distributed.sharding import constraint
from repro.models.params import PSpec
from repro.models.layers import mlp_abstract, mlp_apply


def moe_abstract(cfg: ModelConfig) -> Dict[str, PSpec]:
    m: MoEConfig = cfg.moe
    d, fe = cfg.d_model, m.d_expert
    p: Dict[str, PSpec] = {
        "router": PSpec((d, m.n_experts), (None, None), dtype="float32"),
        "w1": PSpec((m.n_experts, d, fe), ("tp", "fsdp", None)),
        "w3": PSpec((m.n_experts, d, fe), ("tp", "fsdp", None)),
        "w2": PSpec((m.n_experts, fe, d), ("tp", None, "fsdp")),
    }
    if m.n_shared:
        p["shared"] = mlp_abstract(cfg, d_ff=m.n_shared * fe)
    return p


def _capacity(tokens: int, m: MoEConfig) -> int:
    c = int(tokens * m.top_k / m.n_experts * m.capacity_factor)
    return max((c + 7) // 8 * 8, 8)


def _routing(p, xs, m: MoEConfig):
    logits = jnp.einsum("td,de->te", xs.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)               # (T,k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    e = m.n_experts
    frac_assign = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1),
        axis=0) / m.top_k
    frac_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_assign * frac_prob) * m.aux_loss_weight
    return top_w, top_e, aux


def _rank_in_expert(e_flat: jax.Array, tk: int) -> jax.Array:
    order = jnp.argsort(e_flat, stable=True)
    sorted_e = e_flat[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_sorted = jnp.arange(tk, dtype=jnp.int32) - first.astype(jnp.int32)
    return jnp.zeros((tk,), jnp.int32).at[order].set(pos_sorted)


def _expert_ffn(buf, p, cdt):
    g = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(cdt))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w3"].astype(cdt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w2"].astype(cdt))


def moe_apply(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (out, aux_loss)."""
    from repro.distributed.sharding import get_current_mesh
    m: MoEConfig = cfg.moe
    mesh = get_current_mesh()
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    if m.dispatch == "shard_map" and tp > 1 and m.n_experts % tp == 0:
        return _moe_apply_shard_map(p, x, cfg, mesh, tp)
    return _moe_apply_gspmd(p, x, cfg)


def _moe_apply_gspmd(p, x: jax.Array, cfg: ModelConfig):
    """Baseline: GSPMD partitions the capacity-buffer scatter/gather."""
    m: MoEConfig = cfg.moe
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s, d = x.shape
    t = b * s
    k = m.top_k
    e = m.n_experts
    cap = _capacity(t, m)
    xs = x.reshape(t, d)
    top_w, top_e, aux = _routing(p, xs, m)

    e_flat = top_e.reshape(-1)                                  # (T*k,)
    w_flat = top_w.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    pos = _rank_in_expert(e_flat, t * k)
    keep = pos < cap
    slot = jnp.where(keep, e_flat * cap + pos, e * cap)         # OOB -> dropped

    buf = jnp.zeros((e * cap, d), cdt)
    buf = buf.at[slot].add(xs[tok_flat].astype(cdt) *
                           keep[:, None].astype(cdt), mode="drop")
    buf = constraint(buf.reshape(e, cap, d), "tp", None, None)
    y = _expert_ffn(buf, p, cdt)
    y = constraint(y, "tp", None, None).reshape(e * cap, d)

    gathered = y[jnp.clip(slot, 0, e * cap - 1)]
    w_keep = (w_flat * keep).astype(cdt)[:, None]
    out = jnp.zeros((t, d), cdt).at[tok_flat].add(gathered * w_keep)

    if m.n_shared:
        out = out + mlp_apply(p["shared"], x, cfg).reshape(t, d)
    return constraint(out.reshape(b, s, d), "dp", None, None), aux


def _moe_apply_shard_map(p, x: jax.Array, cfg: ModelConfig, mesh, tp: int):
    """EP-as-TP manual dispatch (§Perf): each model shard builds only its
    local (E/tp, C, D) buffer from replicated tokens — zero dispatch
    collectives; the combine is a single (T,D) psum, identical to a Megatron
    FFN's.  Routing (and the aux loss) stays outside in GSPMD-land."""
    from repro.distributed.sharding import shard_map, spec as shspec
    from jax.sharding import PartitionSpec as P
    m: MoEConfig = cfg.moe
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s, d = x.shape
    t = b * s
    k = m.top_k
    e = m.n_experts
    e_local = e // tp
    cap = _capacity(t, m)
    xs = x.reshape(t, d)
    top_w, top_e, aux = _routing(p, xs, m)

    tok_spec = shspec("dp", None)       # tokens sharded over data parallelism
    route_spec = shspec("dp", None)
    w_specs = (P("model", None, None),) * 3

    def body(xs_l, te_l, tw_l, w1_l, w3_l, w2_l):
        t_l = xs_l.shape[0]
        cap_l = _capacity(t_l, m)      # per-DP-shard capacity (local tokens)
        lo = jax.lax.axis_index("model") * e_local
        e_flat = te_l.reshape(-1)
        w_flat = tw_l.reshape(-1)
        tok_flat = jnp.repeat(jnp.arange(t_l, dtype=jnp.int32), k)
        pos = _rank_in_expert(e_flat, t_l * k)
        local = (pos < cap_l) & (e_flat >= lo) & (e_flat < lo + e_local)
        slot = jnp.where(local, (e_flat - lo) * cap_l + pos, e_local * cap_l)
        buf = jnp.zeros((e_local * cap_l, d), cdt)
        buf = buf.at[slot].add(xs_l[tok_flat].astype(cdt) *
                               local[:, None].astype(cdt), mode="drop")
        y = _expert_ffn(buf.reshape(e_local, cap_l, d),
                        {"w1": w1_l, "w3": w3_l, "w2": w2_l}, cdt)
        y = y.reshape(e_local * cap_l, d)
        gathered = y[jnp.clip(slot, 0, e_local * cap_l - 1)]
        w_keep = (w_flat * local).astype(cdt)[:, None]
        out_l = jnp.zeros((t_l, d), cdt).at[tok_flat].add(gathered * w_keep)
        return jax.lax.psum(out_l, "model")

    out = shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec, route_spec, route_spec) + w_specs,
        out_specs=tok_spec, check_vma=False,
    )(xs, top_e, top_w, p["w1"], p["w3"], p["w2"])

    if m.n_shared:
        out = out + mlp_apply(p["shared"], x, cfg).reshape(t, d)
    return constraint(out.reshape(b, s, d), "dp", None, None), aux


def moe_reference(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Dense oracle: every token through its top-k experts, no capacity.
    Used by tests to validate the dispatch path."""
    m = cfg.moe
    cdt = jnp.dtype(cfg.compute_dtype)
    b, s, d = x.shape
    xs = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xs.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(xs, cdt)
    for j in range(m.top_k):
        # compute every expert on every token, select (oracle only; O(E*T))
        g = jnp.einsum("td,edf->etf", xs.astype(cdt), p["w1"].astype(cdt))
        u = jnp.einsum("td,edf->etf", xs.astype(cdt), p["w3"].astype(cdt))
        y = jnp.einsum("etf,efd->etd", jax.nn.silu(g) * u, p["w2"].astype(cdt))
        sel = y[top_e[:, j], jnp.arange(xs.shape[0])]
        out = out + sel * top_w[:, j:j + 1].astype(cdt)
    if m.n_shared:
        out = out + mlp_apply(p["shared"], x, cfg).reshape(-1, d)
    return out.reshape(b, s, d)
