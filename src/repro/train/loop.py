"""Training loop: restart-safe, async-checkpointed, straggler-aware.

Fault tolerance model (designed for 1000+ nodes, exercised here in-process):
  * async MDR checkpoints every ``ckpt_every`` steps, atomic commit
  * on (re)start the loop auto-resumes from the newest valid checkpoint —
    a crashed run restarts bit-exactly (tested by killing mid-run)
  * per-step wall-time ring buffer drives straggler detection: steps slower
    than ``straggler_factor`` x the rolling median raise a flag and invoke
    ``on_straggler`` (at scale: re-shard data / evict host; here: logged +
    counted so tests can assert detection)
  * optional progressive gradient compression (error feedback kept in the
    loop state and checkpointed with it)
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
import time
from pathlib import Path
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import manager as ckpt_mgr
from repro.distributed.grad_compress import ef_quantize
from repro.models.model import Model
from repro.optim import adamw


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    straggler_window: int = 16
    straggler_factor: float = 3.0
    grad_compress_planes: int = 0    # 0 = off
    seed: int = 0


class Trainer:
    def __init__(self, model: Model, opt_cfg: adamw.AdamWConfig,
                 tcfg: TrainerConfig,
                 data_fn: Callable[[int], Dict[str, jax.Array]],
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        """``data_fn(step)`` must be a pure function of the step index so a
        restarted run consumes exactly the same stream (resume-exactness)."""
        self.model = model
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.data_fn = data_fn
        self.on_straggler = on_straggler
        self.step_times: collections.deque = collections.deque(
            maxlen=tcfg.straggler_window)
        self.straggler_events = 0
        self.metrics_log: list = []
        self.ckpt = ckpt_mgr.AsyncCheckpointer(tcfg.ckpt_dir)

        planes = tcfg.grad_compress_planes

        def train_step(params, opt_state, ef_resid, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, batch))(params)
            if planes:
                qs = []
                new_resid = []
                for g, r in zip(jax.tree.leaves(grads), jax.tree.leaves(ef_resid)):
                    q, nr = ef_quantize(g, r, planes)
                    qs.append(q)
                    new_resid.append(nr)
                tdef = jax.tree.structure(grads)
                grads = jax.tree.unflatten(tdef, qs)
                ef_resid = jax.tree.unflatten(tdef, new_resid)
            params, opt_state, metrics = adamw.update(grads, opt_state,
                                                      params, opt_cfg)
            metrics["loss"] = loss
            return params, opt_state, ef_resid, metrics

        self._step_fn = jax.jit(train_step)

    # ------------------------------------------------------------ lifecycle --
    def init_or_resume(self):
        m = self.model
        step0 = ckpt_mgr.latest_step(self.tcfg.ckpt_dir)
        params = m.init(jax.random.PRNGKey(self.tcfg.seed))
        opt_state = adamw.init(params, self.opt_cfg)
        ef = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if step0 is not None:
            tree = {"params": params, "opt": opt_state, "ef": ef}
            tree, _ = ckpt_mgr.load(self.tcfg.ckpt_dir, step0, tree)
            params, opt_state, ef = tree["params"], tree["opt"], tree["ef"]
            print(f"[trainer] resumed from step {step0}")
            return params, opt_state, ef, step0
        return params, opt_state, ef, 0

    def run(self, crash_at: Optional[int] = None) -> Dict[str, Any]:
        params, opt_state, ef, start = self.init_or_resume()
        t = self.tcfg
        step = start
        while step < t.total_steps:
            t0 = time.perf_counter()  # includes data fetch: host-side delays
            batch = self.data_fn(step)  # count toward straggler detection
            params, opt_state, ef, metrics = self._step_fn(
                params, opt_state, ef, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            step += 1
            self._track_time(step, dt)
            if step % t.log_every == 0 or step == t.total_steps:
                self.metrics_log.append(
                    {"step": step, "loss": float(metrics["loss"]),
                     "grad_norm": float(metrics["grad_norm"]), "dt": dt})
            if step % t.ckpt_every == 0 or step == t.total_steps:
                self.ckpt.save(step, {"params": params, "opt": opt_state,
                                      "ef": ef})
            if crash_at is not None and step >= crash_at:
                self.ckpt.wait()
                raise RuntimeError(f"injected crash at step {step}")
        self.ckpt.wait()
        return {"params": params, "opt_state": opt_state, "ef": ef,
                "final_step": step, "metrics": self.metrics_log,
                "straggler_events": self.straggler_events}

    # ------------------------------------------------------------ straggler --
    def _track_time(self, step: int, dt: float):
        if len(self.step_times) >= 4:
            med = statistics.median(self.step_times)
            if dt > self.tcfg.straggler_factor * med:
                self.straggler_events += 1
                if self.on_straggler:
                    self.on_straggler(step, dt / med)
        self.step_times.append(dt)


def synthetic_data(cfg, batch: int, seq: int, seed: int = 0):
    """Step-indexed synthetic batches: data_fn(step) is a pure function of
    (seed, step), so restarts resume the stream exactly."""
    def data_fn(step: int) -> Dict[str, jax.Array]:
        rng = np.random.default_rng((seed, step))
        tok = rng.integers(0, cfg.vocab_size, (batch, seq), dtype=np.int32)
        batch_dict = {"labels": jnp.asarray(np.roll(tok, -1, axis=1))}
        if cfg.external_embed:
            batch_dict["embeds"] = jnp.asarray(
                rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32))
        else:
            batch_dict["tokens"] = jnp.asarray(tok)
        if cfg.cross_attn_period:
            batch_dict["vision_states"] = jnp.asarray(
                rng.normal(size=(batch, cfg.n_vision_tokens,
                                 cfg.d_model)).astype(np.float32))
        return batch_dict
    return data_fn
