"""Train/serve step builders (pjit-ready pure functions + their shardings)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.launch.policy import CellPolicy
from repro.models.model import Model
from repro.optim import adamw


def batch_pspec(policy: CellPolicy, ndim: int) -> P:
    return P(policy.batch_axes, *([None] * (ndim - 1)))


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    policy: CellPolicy):
    """Returns (train_step, in/out sharding helper trees)."""
    n_micro = policy.n_micro

    def train_step(params, opt_state, batch):
        def micro_loss(p, mb):
            return model.loss(p, mb)

        if n_micro == 1:
            loss, grads = jax.value_and_grad(micro_loss)(params, batch)
        else:
            def reshape(x):
                b = x.shape[0]
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])
            mbs = jax.tree.map(reshape, batch)

            def body(carry, mb):
                gacc, lacc = carry
                l, g = jax.value_and_grad(micro_loss)(params, mb)
                gacc = jax.tree.map(jnp.add, gacc, g)
                return (gacc, lacc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro

        new_params, new_opt, metrics = adamw.update(grads, opt_state, params,
                                                    opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        cfg = model.cfg
        if cfg.encoder_only:
            logits = model.forward(params,
                                   tokens=batch.get("tokens"),
                                   embeds=batch.get("embeds"))
            return logits[:, -1, :], None
        logits, caches = model.prefill(params,
                                       tokens=batch.get("tokens"),
                                       embeds=batch.get("embeds"),
                                       vision_states=batch.get("vision_states"))
        return logits, caches
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, index, batch):
        return model.decode_step(params, cache, index, batch["tokens"],
                                 vision_states=batch.get("vision_states"))
    return decode_step


# ------------------------------------------------------- sharding builders --

def train_shardings(model: Model, policy: CellPolicy, mesh,
                    opt_cfg: adamw.AdamWConfig):
    pspecs = model.partition_specs()
    opt_specs = adamw.state_partition_specs(pspecs)
    ns = lambda spec: NamedSharding(mesh, spec)
    param_sh = jax.tree.map(ns, pspecs)
    opt_sh = jax.tree.map(ns, opt_specs,
                          is_leaf=lambda x: isinstance(x, P))
    return param_sh, opt_sh


def batch_shardings(batch_specs: Dict[str, jax.ShapeDtypeStruct],
                    policy: CellPolicy, mesh):
    out = {}
    for k, v in batch_specs.items():
        out[k] = NamedSharding(mesh, batch_pspec(policy, len(v.shape)))
    return out


_SEQ_KEYS = ("k", "v", "ckv", "kr", "ks", "vs")


def cache_shardings(cache_structs, policy: CellPolicy, mesh):
    """Decode caches: batch dim (axis 1, after the layer-stack dim) over the
    batch axes; attention caches' L axis (axis 2) over 'model' when the
    policy picked flash-decoding seq-sharding."""
    batch = tuple(a for a in policy.batch_axes if a in mesh.axis_names) or None
    def f(path, x):
        key = path[-1].key if hasattr(path[-1], "key") else None
        spec = [None] * len(x.shape)
        if len(x.shape) >= 2:
            spec[1] = batch
        if policy.seq_shard and key in _SEQ_KEYS and len(x.shape) >= 3:
            spec[2] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(f, cache_structs)
