"""Progressive retrieval with guaranteed QoI error control (paper §6.2, Alg 3).

QoI families (pointwise, per [39]):
  * ``sum_squares``  f = sum_i v_i^2        (the paper's V_total)
  * ``magnitude``    f = sqrt(sum_i v_i^2)
  * ``linear``       f = sum_i a_i v_i
  * ``product``      f = v_0 * v_1

Error estimates are conservative given per-variable max-norm bounds eps_i:
  |x^2 - xh^2|           <= eps*(2|xh| + eps)
  |sqrt(g) - sqrt(gh)|   <= min(sqrt(dg), dg/(sqrt(max(gh-dg,0)) + sqrt(gh)))
  |sum a_i v_i - ^|      <= sum |a_i| eps_i
  |xy - xh yh|           <= |xh| eps_y + |yh| eps_x + eps_x eps_y

Three next-error-bound estimators (paper §6.2): CP (decay + single-point
re-evaluation on stale data), MA (fetch one more merged plane group per
variable), MAPE (proportional jump eps/p with p = tau'/tau, switching to MA
when p <= c).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.retrieve import ProgressiveReader


@dataclasses.dataclass(frozen=True)
class QoI:
    kind: str
    coeffs: Optional[Tuple[float, ...]] = None  # for 'linear'


V_TOTAL = QoI("sum_squares")


def qoi_value(vs: Sequence[jax.Array], q: QoI) -> jax.Array:
    vs = [jnp.asarray(v, jnp.float32) for v in vs]
    if q.kind == "sum_squares":
        return sum(v * v for v in vs)
    if q.kind == "magnitude":
        return jnp.sqrt(sum(v * v for v in vs))
    if q.kind == "linear":
        return sum(float(a) * v for a, v in zip(q.coeffs, vs))
    if q.kind == "product":
        return vs[0] * vs[1]
    raise ValueError(q.kind)


def qoi_error_pointwise(v_hats: Sequence[jax.Array], eps: Sequence[float],
                        q: QoI) -> jax.Array:
    """Pointwise conservative bound |f(v) - f(v_hat)| given |v_i - v_hat_i| <= eps_i."""
    vh = [jnp.asarray(v, jnp.float32) for v in v_hats]
    e = [jnp.float32(x) for x in eps]
    if q.kind in ("sum_squares", "magnitude"):
        dg = sum(ei * (2.0 * jnp.abs(v) + ei) for v, ei in zip(vh, e))
        if q.kind == "sum_squares":
            return dg
        gh = sum(v * v for v in vh)
        lo = jnp.sqrt(jnp.maximum(gh - dg, 0.0))
        denom = lo + jnp.sqrt(gh)
        ratio = jnp.where(denom > 0, dg / jnp.maximum(denom, 1e-30), jnp.inf)
        return jnp.minimum(jnp.sqrt(dg), ratio)
    if q.kind == "linear":
        return sum(abs(float(a)) * ei for a, ei in zip(q.coeffs, e)) * jnp.ones_like(vh[0])
    if q.kind == "product":
        x, y = vh
        ex, ey = e
        return jnp.abs(x) * ey + jnp.abs(y) * ex + ex * ey
    raise ValueError(q.kind)


@jax.jit
def _max_and_argmax(x: jax.Array):
    flat = x.reshape(-1)
    i = jnp.argmax(flat)
    return flat[i], i


# ----------------------------------------------------------- Algorithm 3 ----

@dataclasses.dataclass
class QoIRetrievalResult:
    values: List[np.ndarray]         # reconstructed variables
    tau_estimated: float             # final max estimated QoI error (tau')
    tau_requested: float
    iterations: int
    bytes_fetched: int
    bitrate: float                   # bits per element, summed over variables
    eps_final: List[float]
    converged: bool
    # plane groups the readers dropped under the degrade policy during THIS
    # call.  converged=False together with degraded_groups > 0 means tau was
    # unattainable because of unreachable data, not because the stored
    # precision ran out — the loop stops at the (degradation-raised) floor
    # instead of spinning, and tau_estimated reports the honest achieved
    # error bound.
    degraded_groups: int = 0
    # per Algorithm-3 iteration: bytes fetched, delta plane bytes actually
    # decoded (incremental engine), and the full-decode baseline (what a
    # from-scratch decode of the iteration's state would run through the
    # bitplane kernels) — benchmarks/qoi_benchmarks.py reports these.
    per_iteration: List[Dict[str, int]] = dataclasses.field(
        default_factory=list)


# Cap for the CP estimator's halving loop: pathological tau values (e.g.
# denormal-small relative to the achieved bounds) would otherwise spin
# through hundreds of subnormal halvings before the estimate moves.  64
# halvings take eps below 2^-64 of its start — past any float32 data scale.
CP_MAX_HALVINGS = 64


def _point_estimate(vh_at_p: np.ndarray, eps: np.ndarray, q: QoI) -> float:
    """Scalar QoI error estimate at one point (CP's stale re-evaluation)."""
    return float(np.asarray(qoi_error_pointwise(
        [jnp.asarray(v) for v in vh_at_p], list(eps), q)))


def _qoi_scale(amaxs: np.ndarray, q: QoI) -> float:
    """Maximal value of the QoI itself (the paper's init denominator)."""
    if q.kind in ("sum_squares",):
        return float(np.sum(amaxs ** 2))
    if q.kind == "magnitude":
        return float(np.sqrt(np.sum(amaxs ** 2)))
    if q.kind == "linear":
        return float(np.sum(np.abs(q.coeffs) * amaxs))
    if q.kind == "product":
        return float(np.prod(amaxs[:2]))
    raise ValueError(q.kind)


def progressive_qoi_retrieve(
    readers: Sequence[ProgressiveReader],
    q: QoI,
    tau: float,
    method: str = "mape",
    c: float = 10.0,
    max_iters: int = 100,
) -> QoIRetrievalResult:
    """Algorithm 3: iterate (fetch -> recompose -> estimate) until tau' <= tau.

    The loop is device-resident end to end: reconstructions come back as
    device arrays (``retrieve_device``/``reconstruct_device`` reuse each
    reader's cached incremental state, so an iteration costs only its delta
    decode + recompose suffix), the QoI error field and its max/argmax are
    evaluated on device, and only the tau' scalar (plus, for CP, the values
    at the argmax point) crosses to host per iteration — full arrays are
    materialized exactly once, at return."""
    n_v = len(readers)
    ranges = np.array([r.ref.data_range for r in readers])
    amaxs = np.array([r.ref.data_amax for r in readers])

    # initial data error bounds: relative value of tau over the QoI's maximal
    # value, multiplied with the value range of the data (paper §6.2).
    tau_scale = _qoi_scale(amaxs, q)
    rel = min(tau / max(tau_scale, 1e-30), 1.0)
    eps_req = np.maximum(rel * ranges, 1e-30)

    tau_p = np.inf
    bytes0 = sum(r.total_bytes_fetched for r in readers)
    deg0 = sum(getattr(r, "degraded_count", 0) for r in readers)
    vals: List[jax.Array] = [None] * n_v
    eps_ach = np.zeros(n_v)
    it = 0
    converged = False
    per_iter: List[Dict[str, int]] = []
    bytes_prev = bytes0  # end-of-iteration fetches count toward the iteration
    while it < max_iters:  # that decodes them (MA/MAPE fetch between rounds)
        it += 1
        # per-reader engine counters, not the global STATS: concurrent
        # sessions decoding elsewhere must not pollute this call's metrics
        dec0 = sum(r.delta_decoded_bytes() for r in readers)
        # fetch + recompose each variable toward its current data error bound
        for i, r in enumerate(readers):
            if method == "ma" and it > 1:
                r.fetch_one_more_group()
                vals[i], eps_ach[i] = r.reconstruct_device()
            else:
                vals[i], eps_ach[i], _ = r.retrieve_device(float(eps_req[i]))
        bytes_now = sum(r.total_bytes_fetched for r in readers)
        per_iter.append({
            "iteration": it,
            "bytes_fetched": bytes_now - bytes_prev,
            "delta_plane_bytes": sum(r.delta_decoded_bytes()
                                     for r in readers) - dec0,
            "full_plane_bytes": sum(r.decoded_plane_bytes() for r in readers),
        })
        bytes_prev = bytes_now
        err = qoi_error_pointwise(vals, list(eps_ach), q)
        tau_p_arr, pstar = _max_and_argmax(err)
        tau_p = float(tau_p_arr)
        if tau_p <= tau:
            converged = True
            break
        # floor = nothing fetchable remains anywhere (peek_best skips pieces
        # that can't reduce the bound, e.g. empty ones)
        at_floor = all(r.peek_best()[1] is None for r in readers)
        if at_floor:
            break
        # estimate next data error bounds
        if method == "cp":
            # index into the BROADCAST field: a variable smaller than err
            # (mixed-size fleet) must be expanded first — jnp gathers clamp
            # out-of-range indices silently instead of raising
            p_idx = int(pstar)
            vh_at_p = np.array([
                float(jnp.ravel(jnp.broadcast_to(v, err.shape))[p_idx])
                for v in vals])
            nxt = eps_ach.copy()
            for _ in range(CP_MAX_HALVINGS):
                if _point_estimate(vh_at_p, nxt, q) <= tau:
                    break
                nxt = nxt / 2.0
            eps_req = nxt
        elif method == "ma":
            pass  # handled by fetch_one_more_group above
        elif method == "mape":
            p = tau_p / tau
            if p > c:
                eps_req = eps_ach / p
            else:
                for r in readers:
                    r.fetch_one_more_group()
        else:
            raise ValueError(method)

    total_bytes = sum(r.total_bytes_fetched for r in readers) - bytes0
    # bitrate per stored value across the (possibly mixed-size) fleet
    n_vals = sum(r.ref.n_elements for r in readers)
    return QoIRetrievalResult(
        values=[np.asarray(v) for v in vals], tau_estimated=tau_p,
        tau_requested=tau, iterations=it, bytes_fetched=total_bytes,
        bitrate=8.0 * total_bytes / max(n_vals, 1),
        eps_final=list(eps_ach), converged=converged, per_iteration=per_iter,
        degraded_groups=sum(getattr(r, "degraded_count", 0)
                            for r in readers) - deg0)
