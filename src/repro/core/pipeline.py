"""Pipeline optimization (paper §6.1, Fig 4): chunked refactor/reconstruct
with copy/compute overlap.

The Host-Device Execution Model (HDEM) gives one device two independent DMA
engines plus a compute engine.  We map the Fig-4 DAGs onto three worker
queues:

  Q1 (H2D copy)  -- prefetch of the *next* chunk's input     (green boxes)
  Q2 (compute)   -- decompose + bitplane encode + lossless   (blue/yellow)
  Q3 (D2H copy)  -- serialization of the *previous* chunk    (red boxes)

Fig-4 dependency edges enforced:
  refactor:   S -> I  (prefetch starts once the previous serialize frees DMA1)
              I -> Z  (prefetch must land before lossless of current chunk)
              O overlaps with next chunk's decompose+encode
  reconstruct: X -> I (input prefetch delayed until decompress done)
               X -> O (store of previous result delayed until decode start)

Dispatch-ahead (fused write path): with ``fused=True`` the compute stage is
split into *dispatch* (one jitted launch of the whole decompose -> quantize
-> bitplane-encode chain per chunk, ``core.refactor_fused``) and *finish*
(host-side lossless selection + manifest assembly, which synchronizes).
The refactor driver keeps up to ``dispatch_ahead`` (>= 2 by default)
dispatched chunks in flight PER DEVICE, drains the whole window in one
batched finish (one scalar gather + one stacked codec pass — 3 host syncs
per drain, amortized ``3 / (dispatch_ahead * n_shards)`` per chunk), and
refills every device queue from the prefetcher before the host blocks on a
drain, so chunk k+1's fused encode runs on device while chunk k's lossless
pack and serialize run on host.  To keep the
pipelined path sync-free per chunk, ``_copy_in`` only calls
``block_until_ready`` when stage timing is enabled (``stage_timing``,
default: serial mode only) — stage timers need the barrier, the overlap
path must not pay it.  ``overlap_map``'s feeder look-ahead is likewise
configurable (``depth``) on the reconstruct pipeline and the store
retrieval service.

On TPU/GPU the copies are real DMA transfers; on this CPU container they are
host memcpys, so the measured overlap is structural rather than
bandwidth-bound (benchmarks report both pipelined and serial modes).
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lossless as ll
from repro.core import lossless_batch as lb
from repro.core import refactor as rf
from repro.core import refactor_fused as rff
from repro.core import retrieve as rtv
from repro.core import sharded as shd
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro import tune as tn


@dataclasses.dataclass
class PipelineStats:
    chunks: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    wall_s: float = 0.0
    copy_in_s: float = 0.0
    compute_s: float = 0.0
    copy_out_s: float = 0.0

    @property
    def throughput_gbps(self) -> float:
        return self.bytes_in / max(self.wall_s, 1e-9) / 1e9


def _chunk_slices(n: int, chunk: int) -> List[slice]:
    return [slice(i, min(i + chunk, n)) for i in range(0, n, chunk)]


def _sync_stage(dev) -> None:
    """Hard device barrier for stage timing.  Module-level so tests can
    count that the pipelined write path never calls it per chunk."""
    jax.block_until_ready(dev)


def _block_stage(out):
    """Stage-boundary barrier: JAX dispatch is async, so a stage must not
    stop its timer while its device work is still in flight — PipelineStats
    would attribute the execution to whichever later stage synchronizes
    first.  Blocks on any device arrays in the stage output, including
    segment payloads nested in a ``Refactored`` (not a pytree, so it needs
    the explicit walk).  Today's stages already end in explicit host
    materialization (the batched codec engine's ``host_sync`` /
    ``np.asarray``), so this is a guard for device-resident payloads rather
    than a load-bearing sync; the serial-mode stage-sum test
    (tests/test_pipeline_stats.py) pins the no-skew property."""
    if isinstance(out, rf.Refactored):
        jax.block_until_ready([a for p in out.pieces
                               for seg in (p.sign_seg, *p.groups)
                               for a in seg.payload.values()
                               if isinstance(a, jax.Array)])
    else:
        jax.block_until_ready(out)
    return out


def overlap_map(n_items: int,
                stage1: Callable[[int], object],
                stage2: Callable[[int, object], object],
                pipelined: bool = True,
                depth: int = 1) -> List[object]:
    """Two-stage overlapped map with the Fig-4 X->I dependency structure.

    ``stage1(i)`` (I/O-bound: fetch/decompress/deserialize) runs on a feeder
    thread at most ``depth`` items ahead; ``stage2(i, s1)`` (compute-bound:
    decode/recompose) runs on the calling thread.  Order is preserved and a
    stage-1 exception is re-raised on the caller.  With ``pipelined=False``
    the stages run strictly serially (the paper's baseline mode).

    This is the single overlap primitive shared by the chunked reconstruct
    pipeline and the store retrieval service."""
    out: List[object] = [None] * n_items
    if not pipelined or n_items <= 1:
        for i in range(n_items):
            out[i] = stage2(i, stage1(i))
        return out

    ready: "queue.Queue[tuple]" = queue.Queue(maxsize=max(depth, 1))
    cancel = threading.Event()

    def feeder():
        for i in range(n_items):
            if cancel.is_set():
                break
            try:
                ready.put((i, stage1(i), None))
            except Exception as exc:  # noqa: BLE001 - forwarded to caller
                ready.put((i, None, exc))
                return
        ready.put((-1, None, None))

    # the feeder joins the caller's context: its spans land in the caller's
    # trace and its counter mutations in the caller's context-local stats
    threading.Thread(target=obs_trace.wrap_for_thread(feeder),
                     daemon=True).start()
    while True:
        i, s1, exc = ready.get()
        if exc is not None:
            raise exc  # feeder already exited; nothing left to drain
        if i < 0:
            break
        try:
            out[i] = stage2(i, s1)
        except BaseException:
            # stop the feeder (it runs at most `depth` more stage1 calls)
            # and drain to its sentinel so the thread exits instead of
            # leaking parked on the bounded put.
            cancel.set()
            while True:
                j, _, e2 = ready.get()
                if j < 0 or e2 is not None:
                    break
            raise
    return out


class ChunkedRefactorPipeline:
    """Refactor a large (possibly larger-than-device-memory) array in chunks.

    ``pipelined=False`` executes the same stages strictly serially (the
    paper's Fig-9 baseline); ``pipelined=True`` overlaps the three queues
    with the Fig-4 dependency edges, and additionally dispatch-ahead: the
    fused write engine launches chunk k+1's whole encode chain (one jitted
    dispatch) before chunk k's host-side lossless/serialize work runs, up
    to ``dispatch_ahead`` chunks in flight.

    ``stage_timing`` controls whether stages hard-synchronize so the
    per-stage timers attribute execution rather than dispatch.  Default is
    ``None``: enabled in serial mode (the stage-sum contract of
    tests/test_pipeline_stats.py), disabled in pipelined mode — the overlap
    path must not pay a per-chunk ``block_until_ready``.

    ``mesh`` shards the write across devices (``core.sharded``): chunks are
    placed round-robin on the mesh's chunk-axis devices and each chunk's
    fused dispatch runs on its owning device, so dispatch-ahead becomes
    dispatch-per-*device*-ahead — up to ``dispatch_ahead`` chunks in flight
    on EACH device.  ``mesh=None`` (default) is exactly today's
    single-device path; a mesh of one device is byte-identical to it.
    """

    def __init__(self, chunk_elems: Optional[int] = None,
                 pipelined: bool = True,
                 levels: int = 2, design: Optional[str] = None,
                 hybrid: Optional[ll.HybridConfig] = None,
                 backend: Optional[str] = None,
                 mag_bits: Optional[int] = None,
                 sink: Optional[Callable[[int, rf.Refactored], bytes]] = None,
                 fused: bool = True, dispatch_ahead: Optional[int] = None,
                 stage_timing: Optional[bool] = None,
                 mesh: shd.MeshLike = None,
                 config: Optional[tn.RefactorConfig] = None,
                 use_tune_cache: bool = True):
        # knob resolution order (most local wins): explicit legacy kwargs >
        # explicit config= > cached autotuned winner (out/tune, consulted by
        # default when no config is given) > built-in defaults
        force = hybrid.force if hybrid is not None else None
        base = tn.as_config(config, design=design, mag_bits=mag_bits,
                            hybrid=hybrid, backend=backend,
                            dispatch_ahead=dispatch_ahead,
                            chunk_elems=chunk_elems)
        if config is None and use_tune_cache:
            mesh_probe = shd.resolve_mesh(
                mesh if mesh is not None else base.mesh_devices)
            n_dev = (mesh_probe.devices.size if mesh_probe is not None else 1)
            cached = tn.cached_config(
                shape=(base.chunk_elems or (1 << 20),), levels=levels,
                backend=base.backend, n_devices=n_dev)
            if cached is not None:
                base = tn.as_config(cached, design=design, mag_bits=mag_bits,
                                    hybrid=hybrid, backend=backend,
                                    dispatch_ahead=dispatch_ahead,
                                    chunk_elems=chunk_elems)
        self.config = base
        self.chunk_elems = base.chunk_elems or (1 << 20)
        self.pipelined = pipelined
        self.levels = levels
        self.design = base.design
        self.hybrid = base.hybrid(force=force)
        self.backend = base.backend
        self.mag_bits = base.mag_bits
        # sink(chunk_idx, refactored) -> serialized bytes: lets a store writer
        # address individual segments (repro.store.writer) instead of getting
        # one opaque blob per chunk.  Chunks reach the sink in index order.
        self.sink = sink
        self.fused = fused
        self.dispatch_ahead = max(int(base.dispatch_ahead), 1)
        self.stage_timing = (not pipelined) if stage_timing is None \
            else bool(stage_timing)
        # chunk -> device placement (and the fused dispatch route when a
        # mesh is set); mesh=None keeps placement uncommitted (default device)
        self.sharded = shd.ShardedRefactorPlan(
            mesh if mesh is not None else base.mesh_devices,
            levels=levels, hybrid=self.hybrid, config=base)
        self.mesh = self.sharded.mesh
        self.stats = PipelineStats()

    @property
    def n_shards(self) -> int:
        return self.sharded.n_shards

    def chunk_shards(self, n_chunks: int) -> List[int]:
        """Round-robin chunk -> shard ordinals (recorded in store manifests)."""
        return [self.sharded.shard_for(ci) for ci in range(n_chunks)]

    # -- stages ------------------------------------------------------------
    # Each stage opens a span (``obs.trace``) carrying the chunk index (and
    # owning-device ordinal when a mesh is set).  Spans record wall time
    # WITHOUT any device barrier — dispatch-heavy stages show dispatch
    # latency, the sync-bearing ``finish`` span shows where execution is
    # actually awaited (its host_sync events mark the exact points).  The
    # legacy ``stage_timing`` barrier mode is unchanged and serial-only.
    def _span_attrs(self, ci: int) -> Dict[str, int]:
        if self.mesh is None:
            return {"chunk": ci}
        return {"chunk": ci, "device": self.sharded.shard_for(ci)}

    def _copy_in(self, host_chunk: np.ndarray, ci: int) -> jax.Array:
        t0 = time.perf_counter()
        with obs_trace.span("write.copy_in", **self._span_attrs(ci)):
            dev = self.sharded.place(ci, host_chunk)
            if self.stage_timing:
                # barrier so copy_in_s measures the transfer, not its
                # dispatch; skipped on the overlap path (no per-chunk sync)
                _sync_stage(dev)
        self.stats.copy_in_s += time.perf_counter() - t0
        return dev

    def _dispatch(self, dev_chunk: jax.Array, name: str, ci: int):
        """Launch one chunk's encode.  Fused mode: ONE jitted dispatch, no
        sync — returns a ``refactor_fused.PendingChunk`` whose device work
        overlaps later host stages (on the chunk's owning device when a
        mesh is set).  Non-fused: the full per-piece compute (returns the
        finished ``Refactored``); the committed input keeps the compute on
        the owning device there too.

        The placed input buffer is pipeline-owned (``_copy_in`` device_puts a
        fresh copy), so the fused path donates it to the encode program —
        on GPU/TPU the quantizer reuses the allocation instead of pairing
        every chunk with a fresh one (no-op on CPU, see
        ``refactor_fused.donation_supported``)."""
        t0 = time.perf_counter()
        with obs_trace.span("write.dispatch", **self._span_attrs(ci)):
            if self.fused:
                out = self.sharded.dispatch(ci, dev_chunk, name=name,
                                            donate=True)
            else:
                out = rf.refactor_array(dev_chunk, name=name,
                                        levels=self.levels,
                                        hybrid=self.hybrid, fused=False,
                                        config=self.config)
        self.stats.compute_s += time.perf_counter() - t0
        return out

    def _finish(self, pending) -> rf.Refactored:
        """Resolve a dispatched chunk (fused: scalar sync + lossless engine)."""
        t0 = time.perf_counter()
        out = (rff.finish_encode(pending)
               if isinstance(pending, rff.PendingChunk) else pending)
        if self.stage_timing:
            out = _block_stage(out)
        self.stats.compute_s += time.perf_counter() - t0
        return out

    def _finish_many(self, pendings: List[rff.PendingChunk]
                     ) -> List[rf.Refactored]:
        """Resolve a batch of dispatched chunks: ONE host sync gathers the
        whole batch's scalar metadata across devices and ONE stacked codec
        pass packs every chunk (``sharded.finish_many``) — 3 host syncs per
        drained window, not per chunk."""
        t0 = time.perf_counter()
        outs = self.sharded.finish_many(pendings)
        if self.stage_timing:
            outs = [_block_stage(o) for o in outs]
        self.stats.compute_s += time.perf_counter() - t0
        return outs

    def _compute(self, dev_chunk: jax.Array, name: str, ci: int) -> rf.Refactored:
        return self._finish(self._dispatch(dev_chunk, name, ci))

    def _copy_out(self, ci: int, refd: rf.Refactored) -> bytes:
        t0 = time.perf_counter()
        with obs_trace.span("write.serialize", **self._span_attrs(ci)):
            if self.sink is not None:
                blob = self.sink(ci, refd)
            else:
                blob = rf.refactored_to_bytes(refd)
            obs_trace.event(obs_trace.EV_SERIALIZE, chunk=ci,
                            bytes=len(blob))
        self.stats.copy_out_s += time.perf_counter() - t0
        return blob

    # -- driver --------------------------------------------------------------
    def refactor(self, x: np.ndarray, name: str = "var") -> List[bytes]:
        """Returns one serialized Refactored blob per chunk."""
        with obs_trace.span("write.refactor", name=name):
            return self._refactor(x, name)

    def _refactor(self, x: np.ndarray, name: str) -> List[bytes]:
        flat = np.ascontiguousarray(x).reshape(-1)
        slices = _chunk_slices(flat.shape[0], self.chunk_elems)
        t_start = time.perf_counter()
        # per-chunk budget gauges (write.syncs_per_chunk must stay O(1) on
        # the fused path: 3 — one scalar gather + two in the codec engine)
        syncs0 = lb.STATS.host_syncs
        disp0 = rff.STATS.dispatches
        blobs: List[Optional[bytes]] = [None] * len(slices)
        # async-drain attribution (pipelined path): chunks per device at
        # each drain, drain count, and host-blocked seconds during which a
        # device queue sat empty
        depth_at_drain: collections.Counter = collections.Counter()
        n_drains = [0]
        idle_at_drain = [0.0]

        if not self.pipelined:
            for ci, sl in enumerate(slices):
                dev = self._copy_in(flat[sl], ci)
                refd = self._compute(dev, f"{name}.{ci}", ci)
                blobs[ci] = self._copy_out(ci, refd)
        else:
            # Q1: prefetch (H2D), Q3: serialize (D2H); compute on main thread.
            # The prefetch queue holds at least one placed chunk per shard so
            # a mesh's devices never starve waiting on the H2D stage.
            prefetch_q: "queue.Queue[tuple[int, jax.Array]]" = queue.Queue(
                maxsize=max(2, self.n_shards))
            out_q: "queue.Queue[tuple[int, rf.Refactored]]" = queue.Queue(maxsize=2)
            done = threading.Event()
            errors: List[BaseException] = []  # worker exceptions, re-raised

            def prefetcher():
                try:
                    for ci, sl in enumerate(slices):
                        prefetch_q.put((ci, self._copy_in(flat[sl], ci)))  # S -> I
                except BaseException as exc:  # noqa: BLE001 - to caller
                    errors.append(exc)
                prefetch_q.put((-1, None))

            def serializer():
                # on error, keep draining so the producer never blocks on the
                # bounded queue (a sink exception must not hang refactor()).
                while True:
                    item = out_q.get()
                    if item[0] < 0:
                        break
                    if errors:
                        continue
                    try:
                        blobs[item[0]] = self._copy_out(item[0], item[1])
                    except BaseException as exc:  # noqa: BLE001 - to caller
                        errors.append(exc)
                done.set()

            # workers join the caller's context (wrap_for_thread): their
            # spans land in the caller's trace and their counter mutations
            # in the caller's context-local stats
            t1 = threading.Thread(target=obs_trace.wrap_for_thread(prefetcher),
                                  daemon=True)
            t3 = threading.Thread(target=obs_trace.wrap_for_thread(serializer),
                                  daemon=True)
            t1.start(); t3.start()
            # dispatch-ahead window: chunk k+1's fused encode is dispatched
            # (in flight on device) before chunk k's finish (host lossless
            # selection + pack) runs — up to ``dispatch_ahead`` chunks deep.
            # With a mesh the window is per DEVICE: consecutive chunks land
            # on different devices (round-robin), so ``dispatch_ahead``
            # chunks in flight per device means dispatch_ahead * n_shards
            # in the window before the oldest chunk must finish.  Draining
            # is batched across the whole window (one scalar gather + one
            # stacked codec pass per drain, not per round), and the device
            # queues are opportunistically refilled from the prefetcher
            # BEFORE the host blocks on a drain, so the next dispatches
            # overlap the batched finish.
            window = self.dispatch_ahead * self.n_shards
            inflight: "collections.deque[tuple]" = collections.deque()

            def dispatch_one(cj: int, dev) -> None:
                pend = self._dispatch(dev, f"{name}.{cj}", cj)
                if isinstance(pend, rf.Refactored):
                    # non-fused: _dispatch already completed the chunk;
                    # buffering it would only delay the serializer
                    out_q.put((cj, pend))
                else:
                    inflight.append((cj, pend))

            def refill_nowait() -> None:
                # opportunistic, non-blocking: anything the prefetcher has
                # already staged is dispatched now so every device queue is
                # as deep as possible while the host resolves the batch
                while len(inflight) < window:
                    try:
                        cj, dev = prefetch_q.get_nowait()
                    except queue.Empty:
                        return
                    if cj < 0:
                        prefetch_q.put((cj, dev))  # re-park the sentinel
                        return
                    if errors:
                        continue
                    dispatch_one(cj, dev)

            def drain_batch() -> None:
                # pop exactly the oldest window (deterministic batch size,
                # so the sync budget is counter-testable: 3 host syncs per
                # drain — scalars + codec stats + codec payload), refill
                # the device queues, then resolve the batch in one go
                batch = [inflight.popleft()
                         for _ in range(min(window, len(inflight)))]
                refill_nowait()
                depth_at_drain.update(
                    self.sharded.shard_for(cj) for cj, _ in batch)
                live = {self.sharded.shard_for(cj) for cj, _ in inflight}
                n_drains[0] += 1
                t0 = time.perf_counter()
                outs = self._finish_many([p for _, p in batch])
                # idle-at-drain: devices with an empty queue during this
                # host-blocking finish had nothing to execute — attributable
                # scheduler slack (gauged as write.idle_at_drain_s)
                idle_at_drain[0] += (time.perf_counter() - t0) * sum(
                    1 for d in range(self.n_shards) if d not in live)
                for (cj, _), refd in zip(batch, outs):
                    out_q.put((cj, refd))

            try:
                while True:
                    ci, dev = prefetch_q.get()
                    if ci < 0:
                        break
                    if errors:
                        continue  # drain the prefetcher; skip further compute
                    dispatch_one(ci, dev)
                    while len(inflight) >= window:
                        drain_batch()  # O + next dispatch overlap the finish
                while inflight and not errors:
                    drain_batch()
            except BaseException as exc:  # noqa: BLE001 - compute failed
                errors.append(exc)
                while ci >= 0:  # release the prefetcher parked on its put
                    ci, _ = prefetch_q.get()
            out_q.put((-1, None))
            done.wait()
            if errors:
                raise errors[0]

        self.stats.chunks += len(slices)
        self.stats.bytes_in += flat.nbytes
        self.stats.bytes_out += sum(len(b) for b in blobs)
        self.stats.wall_s += time.perf_counter() - t_start
        if slices:
            m = obs_metrics.REGISTRY.get()
            m.gauge("write.syncs_per_chunk",
                    (lb.STATS.host_syncs - syncs0) / len(slices))
            m.gauge("write.dispatches_per_chunk",
                    (rff.STATS.dispatches - disp0) / len(slices))
            if n_drains[0]:
                for d in range(self.n_shards):
                    m.gauge(f"write.inflight_depth.d{d}",
                            depth_at_drain[d] / n_drains[0])
                m.gauge("write.idle_at_drain_s", idle_at_drain[0])
        return [b for b in blobs if b is not None]


class ChunkedReconstructPipeline:
    """Progressive reconstruction of chunked refactored data (Fig 4b).

    Per-chunk decode runs through the device-resident incremental engine
    (``incremental=True``, default): the compute stage decodes the fetched
    plane groups once, keeps the reconstruction on device, and only the
    final concatenation (the D2H copy-out of Fig 4b) pulls results to host.
    ``incremental=False`` drives the from-scratch oracle readers instead.

    ``depth`` is the overlap feeder's look-ahead (``overlap_map`` depth)
    AND the per-device drain window: staged chunks accumulate until
    ``depth * n_shards`` engines hold undecoded plane groups, then one
    per-device batched pass delta-decodes them all (``sharded.drain``) —
    no global round barrier; a device's engines drain together whenever
    the window fills.  Order and exception propagation are preserved at
    any depth.

    ``mesh`` shards reconstruction across devices (``core.sharded``): each
    chunk's incremental engine state lives on the chunk's round-robin
    owning device, decode kernels run there, and only the final host
    concatenation joins the shards.  ``mesh=None`` is today's single-device
    path (bit-identical; so is a mesh of one device)."""

    def __init__(self, pipelined: bool = True, backend: Optional[str] = None,
                 incremental: bool = True, depth: Optional[int] = None,
                 mesh: shd.MeshLike = None,
                 config: Optional[tn.RefactorConfig] = None):
        # config= replays a store's tuned plan on the read side (kernel
        # tiling + overlap depth); explicit kwargs win, as on the write side
        cfg = tn.as_config(config, backend=backend, depth=depth)
        self.config = cfg
        self.pipelined = pipelined
        self.backend = cfg.backend
        self.incremental = incremental
        self.depth = max(int(cfg.depth), 1)
        self.sharded = shd.ShardedReconstructEngine(
            mesh if mesh is not None else cfg.mesh_devices)
        self.mesh = self.sharded.mesh
        self.stats = PipelineStats()

    def reconstruct(self, blobs: Sequence[bytes], tol: float) -> np.ndarray:
        with obs_trace.span("read.reconstruct", chunks=len(blobs)):
            return self._reconstruct(blobs, tol)

    def _reconstruct(self, blobs: Sequence[bytes], tol: float) -> np.ndarray:
        t_start = time.perf_counter()
        if not blobs:
            # np.concatenate([]) raises ValueError; an empty chunk list is a
            # valid zero-length dataset (e.g. refactoring an empty array)
            self.stats.wall_s += time.perf_counter() - t_start
            return np.zeros((0,), np.float32)
        outs: List[Optional[jax.Array]] = [None] * len(blobs)

        def _attrs(ci: int) -> Dict[str, int]:
            if self.mesh is None:
                return {"chunk": ci}
            return {"chunk": ci, "device": self.sharded.shard_for(ci)}

        def decompress(ci: int) -> rtv.ProgressiveReader:
            t0 = time.perf_counter()
            with obs_trace.span("read.decompress", **_attrs(ci)):
                reader = rtv.ProgressiveReader(
                    rf.refactored_from_bytes(blobs[ci]),
                    backend=self.backend,
                    incremental=self.incremental,
                    device=self.sharded.device_for(ci),
                    config=self.config)
            self.stats.copy_in_s += time.perf_counter() - t0
            return reader

        # Async per-device drains: each chunk's plan+fetch stages its delta
        # plane groups on the chunk's engine WITHOUT decoding (``read.stage``);
        # once a window of ``depth * n_shards`` chunks is staged, ONE
        # per-device batched pass (``sharded.drain`` -> ``reconstruct.
        # batch_apply_pending``) delta-decodes every staged engine — decode
        # launches amortize across the window and never mix devices — then
        # each chunk recomposes from its (already decoded) engine state.
        staged: List[tuple] = []
        window = max(self.depth * self.sharded.n_shards, 1)

        def flush() -> None:
            if not staged:
                return
            t0 = time.perf_counter()
            engines = [r.engine for _, r in staged if r.engine is not None]
            if engines:
                with obs_trace.span("read.drain", chunks=len(engines)):
                    self.sharded.drain(engines)
            for cj, reader in staged:
                with obs_trace.span("read.recompose", **_attrs(cj)):
                    xh, _ = reader.reconstruct_device()
                    outs[cj] = _block_stage(xh)
            staged.clear()
            self.stats.compute_s += time.perf_counter() - t0

        def recompose(ci: int, reader: rtv.ProgressiveReader) -> None:
            t0 = time.perf_counter()
            with obs_trace.span("read.stage", **_attrs(ci)):
                fetched = reader.stage_retrieve(tol)
            self.stats.compute_s += time.perf_counter() - t0
            self.stats.bytes_in += fetched
            staged.append((ci, reader))
            if len(staged) >= window:
                flush()

        # X -> I edge: upcoming chunks' deserialization+fetch happens on the
        # overlap_map feeder thread, at most ``depth`` chunks ahead of the
        # compute stage.
        overlap_map(len(blobs), decompress, recompose,
                    pipelined=self.pipelined, depth=self.depth)
        flush()

        self.stats.chunks += len(blobs)
        t0 = time.perf_counter()
        out = np.concatenate([np.asarray(o).reshape(-1) for o in outs])
        self.stats.copy_out_s += time.perf_counter() - t0
        self.stats.bytes_out += out.nbytes
        self.stats.wall_s += time.perf_counter() - t_start
        return out
