"""Pipeline optimization (paper §6.1, Fig 4): chunked refactor/reconstruct
with copy/compute overlap.

The Host-Device Execution Model (HDEM) gives one device two independent DMA
engines plus a compute engine.  We map the Fig-4 DAGs onto three worker
queues:

  Q1 (H2D copy)  -- prefetch of the *next* chunk's input     (green boxes)
  Q2 (compute)   -- decompose + bitplane encode + lossless   (blue/yellow)
  Q3 (D2H copy)  -- serialization of the *previous* chunk    (red boxes)

Fig-4 dependency edges enforced:
  refactor:   S -> I  (prefetch starts once the previous serialize frees DMA1)
              I -> Z  (prefetch must land before lossless of current chunk)
              O overlaps with next chunk's decompose+encode
  reconstruct: X -> I (input prefetch delayed until decompress done)
               X -> O (store of previous result delayed until decode start)

On TPU/GPU the copies are real DMA transfers; on this CPU container they are
host memcpys, so the measured overlap is structural rather than
bandwidth-bound (benchmarks report both pipelined and serial modes).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lossless as ll
from repro.core import refactor as rf
from repro.core import retrieve as rtv


@dataclasses.dataclass
class PipelineStats:
    chunks: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    wall_s: float = 0.0
    copy_in_s: float = 0.0
    compute_s: float = 0.0
    copy_out_s: float = 0.0

    @property
    def throughput_gbps(self) -> float:
        return self.bytes_in / max(self.wall_s, 1e-9) / 1e9


def _chunk_slices(n: int, chunk: int) -> List[slice]:
    return [slice(i, min(i + chunk, n)) for i in range(0, n, chunk)]


class ChunkedRefactorPipeline:
    """Refactor a large (possibly larger-than-device-memory) array in chunks.

    ``pipelined=False`` executes the same stages strictly serially (the
    paper's Fig-9 baseline); ``pipelined=True`` overlaps the three queues
    with the Fig-4 dependency edges.
    """

    def __init__(self, chunk_elems: int = 1 << 20, pipelined: bool = True,
                 levels: int = 2, design: str = "register_block",
                 hybrid: ll.HybridConfig = ll.HybridConfig(),
                 backend: str = "auto"):
        self.chunk_elems = chunk_elems
        self.pipelined = pipelined
        self.levels = levels
        self.design = design
        self.hybrid = hybrid
        self.backend = backend
        self.stats = PipelineStats()

    # -- stages ------------------------------------------------------------
    def _copy_in(self, host_chunk: np.ndarray) -> jax.Array:
        t0 = time.perf_counter()
        dev = jax.device_put(host_chunk)
        dev.block_until_ready()
        self.stats.copy_in_s += time.perf_counter() - t0
        return dev

    def _compute(self, dev_chunk: jax.Array, name: str) -> rf.Refactored:
        t0 = time.perf_counter()
        out = rf.refactor_array(dev_chunk, name=name, levels=self.levels,
                                design=self.design, hybrid=self.hybrid,
                                backend=self.backend)
        self.stats.compute_s += time.perf_counter() - t0
        return out

    def _copy_out(self, refd: rf.Refactored) -> bytes:
        t0 = time.perf_counter()
        blob = rf.refactored_to_bytes(refd)
        self.stats.copy_out_s += time.perf_counter() - t0
        return blob

    # -- driver --------------------------------------------------------------
    def refactor(self, x: np.ndarray, name: str = "var") -> List[bytes]:
        """Returns one serialized Refactored blob per chunk."""
        flat = np.ascontiguousarray(x).reshape(-1)
        slices = _chunk_slices(flat.shape[0], self.chunk_elems)
        t_start = time.perf_counter()
        blobs: List[Optional[bytes]] = [None] * len(slices)

        if not self.pipelined:
            for ci, sl in enumerate(slices):
                dev = self._copy_in(flat[sl])
                refd = self._compute(dev, f"{name}.{ci}")
                blobs[ci] = self._copy_out(refd)
        else:
            # Q1: prefetch (H2D), Q3: serialize (D2H); compute on main thread.
            prefetch_q: "queue.Queue[tuple[int, jax.Array]]" = queue.Queue(maxsize=2)
            out_q: "queue.Queue[tuple[int, rf.Refactored]]" = queue.Queue(maxsize=2)
            done = threading.Event()

            def prefetcher():
                for ci, sl in enumerate(slices):
                    prefetch_q.put((ci, self._copy_in(flat[sl])))  # S -> I edge via maxsize
                prefetch_q.put((-1, None))

            def serializer():
                while True:
                    item = out_q.get()
                    if item[0] < 0:
                        break
                    ci, refd = item
                    blobs[ci] = self._copy_out(refd)
                done.set()

            t1 = threading.Thread(target=prefetcher, daemon=True)
            t3 = threading.Thread(target=serializer, daemon=True)
            t1.start(); t3.start()
            while True:
                ci, dev = prefetch_q.get()
                if ci < 0:
                    break
                refd = self._compute(dev, f"{name}.{ci}")  # I -> Z honored: input resident
                out_q.put((ci, refd))                      # O overlaps next compute
            out_q.put((-1, None))
            done.wait()

        self.stats.chunks += len(slices)
        self.stats.bytes_in += flat.nbytes
        self.stats.bytes_out += sum(len(b) for b in blobs)
        self.stats.wall_s += time.perf_counter() - t_start
        return [b for b in blobs if b is not None]


class ChunkedReconstructPipeline:
    """Progressive reconstruction of chunked refactored data (Fig 4b)."""

    def __init__(self, pipelined: bool = True, backend: str = "auto"):
        self.pipelined = pipelined
        self.backend = backend
        self.stats = PipelineStats()

    def reconstruct(self, blobs: Sequence[bytes], tol: float) -> np.ndarray:
        t_start = time.perf_counter()
        outs: List[Optional[np.ndarray]] = [None] * len(blobs)

        def decompress(ci: int) -> rtv.ProgressiveReader:
            t0 = time.perf_counter()
            reader = rtv.ProgressiveReader(rf.refactored_from_bytes(blobs[ci]),
                                           backend=self.backend)
            self.stats.copy_in_s += time.perf_counter() - t0
            return reader

        def recompose(ci: int, reader: rtv.ProgressiveReader) -> None:
            t0 = time.perf_counter()
            xh, _, fetched = reader.retrieve(tol)
            outs[ci] = xh
            self.stats.compute_s += time.perf_counter() - t0
            self.stats.bytes_in += fetched

        if not self.pipelined:
            for ci in range(len(blobs)):
                recompose(ci, decompress(ci))
        else:
            # X -> I edge: the next chunk's deserialization+fetch happens on a
            # side thread but is released only after this chunk's decompress.
            ready: "queue.Queue[tuple[int, rtv.ProgressiveReader]]" = queue.Queue(maxsize=1)

            def feeder():
                for ci in range(len(blobs)):
                    ready.put((ci, decompress(ci)))
                ready.put((-1, None))

            threading.Thread(target=feeder, daemon=True).start()
            while True:
                ci, reader = ready.get()
                if ci < 0:
                    break
                recompose(ci, reader)

        self.stats.chunks += len(blobs)
        out = np.concatenate([o.reshape(-1) for o in outs])
        self.stats.bytes_out += out.nbytes
        self.stats.wall_s += time.perf_counter() - t_start
        return out
