"""End-to-end data refactoring (paper Fig 1, write path).

refactor_array:  x -> multilevel decompose -> per-piece exponent alignment ->
bitplane encode -> merged plane groups -> Algorithm-2 hybrid lossless ->
``Refactored`` (segments + manifest).  The manifest carries everything the
reader needs for error-controlled progressive retrieval: per-piece exponent,
element count, per-group stored sizes and methods.

Pieces are indexed [0]=coarsest corner, [1]=detail_L ... [levels]=detail_1,
matching ``decompose.decompose``.  Piece error weights for the max-norm bound
are w_0 = 1 (corner), w_k = 2^ndim - 1 (details) per ``decompose.error_bound``.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import align as al
from repro.core import decompose as dc
from repro.core import lossless as ll
from repro.core import lossless_batch as lb
from repro.kernels import ops as kops

_WIRE_MAGIC = 0x4D445230  # 'MDR0' single-blob wire format


@dataclasses.dataclass
class PieceMeta:
    n: int                      # elements in this piece
    exponent: int               # alignment exponent e  (max|x| <= 2**e)
    weight: float               # error weight in the recomposition bound
    sign_seg: ll.Segment
    groups: List[ll.Segment]    # MSB-first merged plane groups
    group_planes: List[int]     # planes per group (last may be short)

    @property
    def mag_bits(self) -> int:
        return sum(self.group_planes)


@dataclasses.dataclass
class Refactored:
    """Refactored representation of one array ('variable')."""
    name: str
    shape: Tuple[int, ...]
    levels: int
    design: str
    mag_bits: int
    group_size: int
    data_amax: float
    data_range: float
    pieces: List[PieceMeta]

    @property
    def n_elements(self) -> int:
        return int(np.prod(self.shape))

    @property
    def stored_bytes(self) -> int:
        return sum(p.sign_seg.stored_bytes + sum(g.stored_bytes for g in p.groups)
                   for p in self.pieces)

    # -- error model -------------------------------------------------------
    def piece_eps(self, piece: int, planes_kept: int) -> float:
        pm = self.pieces[piece]
        if pm.n == 0:
            return 0.0  # no coefficients -> no truncation error contribution
        return al.truncation_error(pm.exponent, planes_kept, self.mag_bits)

    def bound(self, planes_per_piece: Sequence[int]) -> float:
        eps = [self.piece_eps(i, p) for i, p in enumerate(planes_per_piece)]
        return dc.error_bound(eps, ndim=len(self.shape), data_amax=self.data_amax)


def _group_plane_split(mag_bits: int, group_size: int) -> List[int]:
    group_planes: List[int] = []
    left = mag_bits
    while left > 0:
        g = min(group_size, left)
        group_planes.append(g)
        left -= g
    return group_planes


def _device_bytes(planes: jax.Array) -> jax.Array:
    """(P, W) uint32 planes -> flat uint8 blob, on device.

    Matches ``np.asarray(planes).reshape(-1).view(np.uint8)`` byte-for-byte
    (bitcast minor dimension is the little-endian byte order numpy's view
    sees; tests/test_lossless_batch.py pins this)."""
    flat = planes.reshape(-1)
    return jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(-1)


def refactor_array(
    x: np.ndarray | jax.Array,
    name: str = "var",
    levels: Optional[int] = None,
    design: Optional[str] = None,
    mag_bits: Optional[int] = None,
    hybrid: Optional[ll.HybridConfig] = None,
    backend: Optional[str] = None,
    batched: bool = True,
    fused: Optional[bool] = None,
    config: Optional["tn.RefactorConfig"] = None,
) -> Refactored:
    """Refactor one array.

    With ``fused=True`` (the default when ``batched``) the WHOLE encode
    chain — decompose, alignment/quantization, bitplane encode, group blob
    slicing, and the scalar pass — runs as ONE cached jitted dispatch per
    chunk through ``refactor_fused`` (see that module); the lossless engine
    then consumes the stacked blob rows directly.  ``fused=False,
    batched=True`` is the piece-at-a-time device-resident path (~3 jitted
    dispatches per piece); ``batched=False`` the original per-group path.
    All three produce byte-identical serializations — the slower paths stay
    as bit-exactness oracles, and all three honor the same effective
    ``RefactorConfig`` (``config=`` or legacy kwargs; explicit kwargs win).
    """
    from repro import tune as tn  # local: keep import graph flat
    cfg = tn.as_config(config, design=design, mag_bits=mag_bits,
                       hybrid=hybrid, backend=backend)
    force = hybrid.force if hybrid is not None else None
    design, mag_bits = cfg.design, cfg.resolved_mag_bits()
    hybrid, backend = cfg.hybrid(force=force), cfg.backend
    if fused is None:
        fused = batched
    elif fused and not batched:
        raise ValueError("fused=True requires batched=True: the fused engine "
                         "replaces the batched path, not the per-group oracle")
    if fused and batched:
        from repro.core import refactor_fused as rff  # local: no import cycle
        return rff.refactor_fused(x, name=name, levels=levels,
                                  hybrid=hybrid, config=cfg)
    x = jnp.asarray(x, dtype=jnp.float32)
    if levels is None:
        levels = dc.num_levels(x.shape)
    pieces = dc.decompose(x, levels)
    ndim = x.ndim
    group_planes = _group_plane_split(mag_bits, hybrid.group_size)

    if not batched:
        return _refactor_array_pergroup(x, pieces, name, levels, design,
                                        mag_bits, hybrid, backend,
                                        group_planes, ndim, cfg)

    # -- device-resident batched path ---------------------------------------
    # Stage every piece's planes + per-group blobs on device; collect the
    # scalar outputs (amax/range/exponents) and pull them in ONE device_get.
    scalars: List[jax.Array] = []
    if x.size:
        scalars.append(jnp.max(jnp.abs(x)))
        scalars.append(jnp.max(x) - jnp.min(x))
    blobs: List[jax.Array] = []          # canonical order: per piece sign,
    n_words_all: List[int] = []          # then MSB-first groups
    for piece in pieces:
        mag, sign, e = al.align_encode(piece, mag_bits)
        scalars.append(e)
        planes = kops.encode_bitplanes(
            mag, mag_bits, design, backend=backend,
            tiles_per_block=cfg.tiles_per_block, unroll=cfg.unroll)
        sign_planes = kops.encode_bitplanes(
            sign, 1, design, backend=backend,
            tiles_per_block=cfg.tiles_per_block, unroll=cfg.unroll)
        n_words_all.append(int(planes.shape[1]))
        blobs.append(_device_bytes(sign_planes))
        row = 0
        for g in group_planes:
            blobs.append(_device_bytes(planes[row:row + g]))
            row += g
    host_scalars = list(lb.host_sync(scalars))
    if x.size:
        amax = float(host_scalars.pop(0))
        rng = float(host_scalars.pop(0))
    else:
        amax = rng = 0.0
    exponents = [int(e) for e in host_scalars]

    segs = lb.encode_groups(blobs, hybrid)
    metas: List[PieceMeta] = []
    per_piece = 1 + len(group_planes)
    for pi, piece in enumerate(pieces):
        base = pi * per_piece
        sign_seg = segs[base]
        groups = segs[base + 1:base + per_piece]
        for g, seg in zip(group_planes, groups):
            seg.meta["n_planes"] = g
            seg.meta["n_words"] = n_words_all[pi]
        metas.append(PieceMeta(
            n=int(piece.shape[0]), exponent=exponents[pi],
            weight=1.0 if pi == 0 else float((1 << ndim) - 1),
            sign_seg=sign_seg, groups=groups, group_planes=group_planes))
    return Refactored(name=name, shape=tuple(x.shape), levels=levels,
                      design=design, mag_bits=mag_bits,
                      group_size=hybrid.group_size, data_amax=amax,
                      data_range=rng, pieces=metas)


def _refactor_array_pergroup(x, pieces, name, levels, design, mag_bits,
                             hybrid, backend, group_planes, ndim,
                             cfg) -> Refactored:
    """Original per-(piece, group) path: one host round-trip per group.

    Kept as the bit-exactness oracle for the batched engine (and for
    debugging); produces byte-identical serializations."""
    amax = float(jnp.max(jnp.abs(x))) if x.size else 0.0
    rng = float(jnp.max(x) - jnp.min(x)) if x.size else 0.0
    metas: List[PieceMeta] = []
    for pi, piece in enumerate(pieces):
        mag, sign, e = al.align_encode(piece, mag_bits)
        planes = kops.encode_bitplanes(
            mag, mag_bits, design, backend=backend,
            tiles_per_block=cfg.tiles_per_block, unroll=cfg.unroll)
        sign_planes = kops.encode_bitplanes(
            sign, 1, design, backend=backend,
            tiles_per_block=cfg.tiles_per_block, unroll=cfg.unroll)
        sign_seg = ll.compress_group(np.asarray(sign_planes).view(np.uint8).reshape(-1),
                                     hybrid)
        groups: List[ll.Segment] = []
        row = 0
        planes_np = np.asarray(planes)
        for g in group_planes:
            blob = planes_np[row:row + g].reshape(-1).view(np.uint8)
            seg = ll.compress_group(blob, hybrid)
            seg.meta["n_planes"] = g
            seg.meta["n_words"] = planes_np.shape[1]
            groups.append(seg)
            row += g
        metas.append(PieceMeta(
            n=int(piece.shape[0]), exponent=int(e),
            weight=1.0 if pi == 0 else float((1 << ndim) - 1),
            sign_seg=sign_seg, groups=groups, group_planes=group_planes))
    return Refactored(name=name, shape=tuple(x.shape), levels=levels,
                      design=design, mag_bits=mag_bits,
                      group_size=hybrid.group_size, data_amax=amax,
                      data_range=rng, pieces=metas)


# ------------------------------------------------------------ serialization --
#
# Two layers, so an on-disk store can address plane groups without
# re-encoding anything (repro.store.layout):
#
#   * ``iter_segments`` / ``Segment.to_bytes`` — the canonical segment stream
#     (per piece: sign, then MSB-first groups).  A store writes each blob at
#     its own offset and records (offset, size, method) per segment.
#   * ``refactored_meta`` / ``refactored_from_meta`` — the payload-free
#     header.  Rebuilding from it with stub segments yields a ``Refactored``
#     whose planner sees true stored sizes but holds no payload bytes.
#
# ``refactored_to_bytes`` / ``refactored_from_bytes`` (the single-blob wire
# format used by the pipelines) are thin compositions of the two layers.


def iter_segments(r: Refactored):
    """Yield (piece_idx, kind, group_idx, Segment) in canonical stream order.

    kind is 'sign' (group_idx = -1) or 'group' (group_idx = 0..G-1, MSB
    first).  This order is shared by ``refactored_to_bytes`` and the store
    layout, so offsets computed against it address the same bytes."""
    for pi, p in enumerate(r.pieces):
        yield pi, "sign", -1, p.sign_seg
        for gi, g in enumerate(p.groups):
            yield pi, "group", gi, g


def refactored_meta(r: Refactored) -> Dict:
    """JSON-able payload-free header: everything the retrieval planner and
    error model need, minus the segment payloads."""
    return {
        "name": r.name,
        "shape": list(r.shape),
        "levels": r.levels,
        "design": r.design,
        "mag_bits": r.mag_bits,
        "group_size": r.group_size,
        "amax": r.data_amax,
        "range": r.data_range,
        "pieces": [
            {
                "n": p.n,
                "exponent": p.exponent,
                "weight": p.weight,
                "n_words": int(p.groups[0].meta.get("n_words", 0))
                if p.groups else 0,
                "group_planes": list(p.group_planes),
            }
            for p in r.pieces
        ],
    }


def refactored_from_meta(meta: Dict, segments) -> Refactored:
    """Rebuild a ``Refactored`` from a payload-free header.

    ``segments(piece_idx, kind, group_idx) -> ll.Segment`` supplies each
    segment — either a real decoded segment or a stub carrying
    ``meta["stored_bytes"]`` (see ``ll.Segment.is_stub``)."""
    pieces = []
    for pi, pm in enumerate(meta["pieces"]):
        sign_seg = segments(pi, "sign", -1)
        groups = [segments(pi, "group", gi)
                  for gi in range(len(pm["group_planes"]))]
        pieces.append(PieceMeta(
            n=int(pm["n"]), exponent=int(pm["exponent"]),
            weight=float(pm["weight"]), sign_seg=sign_seg, groups=groups,
            group_planes=[int(g) for g in pm["group_planes"]]))
    return Refactored(
        name=meta["name"], shape=tuple(int(s) for s in meta["shape"]),
        levels=int(meta["levels"]), design=meta["design"],
        mag_bits=int(meta["mag_bits"]), group_size=int(meta["group_size"]),
        data_amax=float(meta["amax"]), data_range=float(meta["range"]),
        pieces=pieces)


def refactored_to_bytes(r: Refactored) -> bytes:
    head = {
        "name": r.name.encode(), "shape": r.shape, "levels": r.levels,
        "design": r.design.encode(), "mag_bits": r.mag_bits,
        "group_size": r.group_size, "amax": r.data_amax, "range": r.data_range,
    }
    parts = [struct.pack("<I", _WIRE_MAGIC)]
    nb = head["name"]; db = head["design"]
    parts.append(struct.pack("<i", len(nb)) + nb)
    parts.append(struct.pack("<i", len(db)) + db)
    parts.append(struct.pack("<iii", r.levels, r.mag_bits, r.group_size))
    parts.append(struct.pack("<dd", r.data_amax, r.data_range))
    parts.append(struct.pack("<i", len(r.shape)) + struct.pack(f"<{len(r.shape)}q", *r.shape))
    parts.append(struct.pack("<i", len(r.pieces)))
    for p in r.pieces:
        parts.append(struct.pack("<qid", p.n, p.exponent, p.weight))
        sb = p.sign_seg.to_bytes()
        parts.append(struct.pack("<q", len(sb)) + sb)
        parts.append(struct.pack("<i", len(p.groups)))
        for g, gp in zip(p.groups, p.group_planes):
            gb = g.to_bytes()
            parts.append(struct.pack("<iq", gp, len(gb)) + gb)
    return b"".join(parts)


def refactored_from_bytes(buf: bytes) -> Refactored:
    try:
        return _refactored_from_bytes(buf)
    except struct.error as exc:  # truncation must surface as ValueError too
        raise ValueError(f"corrupt refactored blob: truncated ({exc})") from exc


def _refactored_from_bytes(buf: bytes) -> Refactored:
    (magic,) = struct.unpack_from("<I", buf, 0)
    if magic != _WIRE_MAGIC:
        raise ValueError("corrupt refactored blob: bad magic")
    off = 4
    (ln,) = struct.unpack_from("<i", buf, off); off += 4
    name = buf[off:off + ln].decode(); off += ln
    (ld,) = struct.unpack_from("<i", buf, off); off += 4
    design = buf[off:off + ld].decode(); off += ld
    levels, mag_bits, group_size = struct.unpack_from("<iii", buf, off); off += 12
    amax, rng = struct.unpack_from("<dd", buf, off); off += 16
    (nd,) = struct.unpack_from("<i", buf, off); off += 4
    shape = struct.unpack_from(f"<{nd}q", buf, off); off += 8 * nd
    (npieces,) = struct.unpack_from("<i", buf, off); off += 4
    pieces = []
    for _ in range(npieces):
        n, e, w = struct.unpack_from("<qid", buf, off); off += struct.calcsize("<qid")
        (ls,) = struct.unpack_from("<q", buf, off); off += 8
        sign_seg = ll.Segment.from_bytes(buf[off:off + ls]); off += ls
        (ng,) = struct.unpack_from("<i", buf, off); off += 4
        groups, gp = [], []
        for _ in range(ng):
            g_planes, lg = struct.unpack_from("<iq", buf, off); off += struct.calcsize("<iq")
            groups.append(ll.Segment.from_bytes(buf[off:off + lg])); off += lg
            gp.append(g_planes)
        pieces.append(PieceMeta(n=n, exponent=e, weight=w, sign_seg=sign_seg,
                                groups=groups, group_planes=gp))
    return Refactored(name=name, shape=tuple(int(s) for s in shape),
                      levels=levels, design=design, mag_bits=mag_bits,
                      group_size=group_size, data_amax=amax, data_range=rng,
                      pieces=pieces)
