"""MGARD-style multilevel interpolation decomposition (N-D, exact inverse).

The MDR-practice variant of MGARD: per level, per axis, odd samples are
predicted by linear interpolation of the even samples; the residuals are the
level's detail coefficients.  The transform is exactly invertible in float
arithmetic (the inverse applies the identical prediction), so refactoring is
lossless before bitplane truncation.

Error propagation (max-norm, conservative — verified by property tests):
inverting one axis gives err(odd) <= err(detail) + avg(err(even)).  The D
sequential axis merges of one level compound: with every detail coefficient
of the level perturbed by eps and the incoming coarse error c, the level
output error is bounded by (2^D - 1) * eps + c  (e.g. D=2: the axis-0 merge
adds the 2*eps-corrupted detail rows to the (eps+c)-corrupted coarse rows ->
3*eps + c).  Hence
    |x - x_hat|_inf <= eps_corner + (2^D - 1) * sum_level eps_level.
``error_bound`` implements exactly that.
"""
from __future__ import annotations

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _split_axis(x: jax.Array, axis: int) -> jax.Array:
    """One 1-D decomposition step along ``axis``: returns [even | detail]."""
    x = jnp.moveaxis(x, axis, -1)
    n = x.shape[-1]
    xe = x[..., 0::2]
    xo = x[..., 1::2]
    ne, no = xe.shape[-1], xo.shape[-1]
    # right neighbor of odd i is even i+1 (duplicate edge when absent)
    right = xe[..., 1:no + 1] if ne > no else jnp.concatenate(
        [xe[..., 1:], xe[..., -1:]], axis=-1)
    pred = 0.5 * (xe[..., :no] + right)
    detail = xo - pred
    out = jnp.concatenate([xe, detail], axis=-1)
    return jnp.moveaxis(out, -1, axis)


def _merge_axis(x: jax.Array, axis: int, n: int) -> jax.Array:
    """Inverse of `_split_axis` for an axis of original length ``n``."""
    x = jnp.moveaxis(x, axis, -1)
    ne = (n + 1) // 2
    no = n - ne
    xe, detail = x[..., :ne], x[..., ne:]
    right = xe[..., 1:no + 1] if ne > no else jnp.concatenate(
        [xe[..., 1:], xe[..., -1:]], axis=-1)
    xo = detail + 0.5 * (xe[..., :no] + right)
    out = jnp.zeros(x.shape[:-1] + (n,), x.dtype)
    out = out.at[..., 0::2].set(xe)
    out = out.at[..., 1::2].set(xo)
    return jnp.moveaxis(out, -1, axis)


def num_levels(shape: Sequence[int], min_size: int = 8, max_levels: int = 6) -> int:
    lv = 0
    dims = list(shape)
    while lv < max_levels and all(d >= 2 * min_size or d == 1 for d in dims):
        dims = [(d + 1) // 2 if d > 1 else 1 for d in dims]
        lv += 1
    return max(lv, 1)


def _coarse_shape(shape: Sequence[int]) -> Tuple[int, ...]:
    # d == 0 stays 0 (empty axes stay empty); d == 1 stays 1
    return tuple((d + 1) // 2 if d > 1 else d for d in shape)


def decompose(x: jax.Array, levels: int) -> List[jax.Array]:
    """x -> [corner, detail_L, detail_{L-1}, ..., detail_1], each flattened.

    detail_k is the detail coefficient set of level k (k=1 is the finest).
    The corner is the coarsest approximation.  Pure function of x; shapes are
    static, so this jits cleanly.
    """
    x = x.astype(jnp.float32)
    pieces_rev: List[jax.Array] = []
    cur = x
    for _ in range(levels):
        shape = cur.shape
        for ax in range(cur.ndim):
            if shape[ax] > 1:
                cur = _split_axis(cur, ax)
        cs = _coarse_shape(shape)
        corner = cur[tuple(slice(0, c) for c in cs)]
        detail = _extract_detail(cur, cs)
        pieces_rev.append(detail)
        cur = corner
    # order: [corner, detail_L (coarsest), ..., detail_1 (finest)]
    return [cur.reshape(-1)] + pieces_rev[::-1]


def _extract_detail(full: jax.Array, cs: Tuple[int, ...]) -> jax.Array:
    """All entries of ``full`` except the coarse corner, flattened (fixed order)."""
    mask = np.ones(full.shape, dtype=bool)
    mask[tuple(slice(0, c) for c in cs)] = False
    idx = np.nonzero(mask.reshape(-1))[0]
    return full.reshape(-1)[jnp.asarray(idx)]


def level_shapes(shape: Sequence[int], levels: int) -> List[Tuple[int, ...]]:
    """Shapes of the working array at each level, finest first."""
    shapes = [tuple(shape)]
    for _ in range(levels):
        shapes.append(_coarse_shape(shapes[-1]))
    return shapes


# --------------------------------------------------- cached recompose plans --
#
# One level of the inverse transform — scatter the coarse corner and the
# level's detail coefficients into the full grid, then merge every axis —
# only depends on the level's full shape.  The scatter indices (a nonzero
# over the corner mask) and the jitted merge program are therefore cached
# per shape: repeated recomposes (the progressive read path reconstructs
# after every fetch) pay neither the index recomputation nor a retrace.
#
# ``recompose`` runs the plan end to end; the incremental engine
# (``core.reconstruct``) runs a *suffix* of the same per-level functions
# against cached intermediates, which keeps it bit-exact with the full pass
# (identical compiled programs over identical inputs).


# Each cached entry retains its scatter indices (O(n_full) ints, held on
# device by the jit executable) until evicted, so the cap is deliberately
# modest: a workload's live set is #levels x #distinct-chunk-shapes, far
# below 64; anything beyond that re-derives the plan on a cache miss rather
# than pinning device memory for shapes no longer in use.
@functools.lru_cache(maxsize=64)
def level_merge_fn(full_shape: Tuple[int, ...]):
    """Jitted ``(coarse, detail) -> full`` merge for one level at
    ``full_shape``, with precomputed scatter indices baked in."""
    cs = _coarse_shape(full_shape)
    mask = np.ones(full_shape, dtype=bool)
    mask[tuple(slice(0, c) for c in cs)] = False
    detail_idx = np.nonzero(mask.reshape(-1))[0]
    corner_idx = np.nonzero(~mask.reshape(-1))[0]
    n_full = int(np.prod(full_shape, dtype=np.int64))

    @jax.jit
    def merge(corner: jax.Array, detail: jax.Array) -> jax.Array:
        out = jnp.zeros(n_full, corner.dtype)
        out = out.at[corner_idx].set(corner.reshape(-1))
        out = out.at[detail_idx].set(detail)
        full = out.reshape(full_shape)
        for ax in range(len(full_shape) - 1, -1, -1):
            if full_shape[ax] > 1:
                full = _merge_axis(full, ax, full_shape[ax])
        return full

    return merge


def recompose_plan(shape: Sequence[int], levels: int):
    """[(full_shape, jitted merge fn)] for stages 1..levels (coarsest first):
    stage ``i`` merges detail piece ``i`` (pieces order: [corner, detail_L,
    ..., detail_1]) into the running coarse approximation."""
    shapes = level_shapes(shape, levels)  # [finest ... coarsest]
    return [(shapes[k - 1], level_merge_fn(shapes[k - 1]))
            for k in range(levels, 0, -1)]


def recompose(pieces: List[jax.Array], shape: Sequence[int], levels: int) -> jax.Array:
    """Inverse of `decompose`."""
    shapes = level_shapes(shape, levels)
    cur = pieces[0].reshape(shapes[-1])
    for i, (_, merge) in enumerate(recompose_plan(shape, levels)):
        cur = merge(cur, pieces[i + 1])
    return cur


def error_bound(eps_pieces: Sequence[float], ndim: int,
                data_amax: float = 0.0) -> float:
    """Max-norm reconstruction error bound from per-piece coefficient errors.

    eps_pieces = [eps_corner, eps_L, ..., eps_1] matching `decompose` output.
    ``data_amax`` adds a float32-roundoff slack for the forward+inverse
    transform itself (the interpolation transform is invertible to O(ulp),
    not bit-exact): 2 * levels * ndim * 2^-24 * amax.  The multiplier was
    calibrated against property tests (worst observed roundoff is ~0.3x it).
    """
    eps_corner, *eps_levels = [float(e) for e in eps_pieces]
    levels = len(eps_levels)
    slack = 2.0 * levels * ndim * (2.0 ** -24) * float(data_amax)
    factor = (1 << ndim) - 1
    return eps_corner + factor * float(np.sum(eps_levels)) + slack
