"""Error-controlled progressive retrieval (paper Fig 1, read path).

``ProgressiveReader`` keeps the fetched-segment state across requests, so
successive retrievals are *incremental*: only the delta plane groups are
fetched (and counted toward bytes_fetched), exactly as in MDR.  With
``incremental=True`` (default) the decode side is incremental too: fetched
groups stream into a device-resident ``core.reconstruct`` engine that
delta-decodes them at their bit offsets and re-runs only the recompose
suffix below the coarsest changed piece; ``incremental=False`` is the
from-scratch full-decode path, kept as the bit-exactness oracle.

Rate allocation is greedy by error-reduction-per-byte over (piece, group)
candidates — the classic MDR allocation — against the conservative max-norm
bound  eps_corner + (2^ndim - 1) * sum(eps_level) + roundoff slack
(``decompose.error_bound``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import align as al
from repro.core import decompose as dc
from repro.core import lossless as ll
from repro.core import lossless_batch as lb
from repro.core import reconstruct as rc
from repro.core.refactor import Refactored
from repro.kernels import ops as kops
from repro.kernels import ref as kref


@dataclasses.dataclass
class _PieceState:
    groups_fetched: int = 0
    planes: Optional[np.ndarray] = None     # oracle mode: (P, W) host prefix
    sign: Optional[np.ndarray] = None       # oracle mode: sign plane (1, W)
    bytes_fetched: int = 0
    # degradation cap: max reachable group count for this piece this session
    # (None = all groups reachable).  Set when a fetch fails under degrade=
    # policy; planning never asks for groups at or beyond the cap, so the
    # reported bound stays honest about what was actually applied.
    cap: Optional[int] = None


class SegmentSource:
    """Where a reader gets segment payloads from.

    The default ``InlineSegmentSource`` serves the in-memory segments held by
    the ``Refactored`` itself; a store-backed source (repro.store) resolves
    (piece, group) to a byte range and fetches exactly that range.  ``sign``
    and ``group`` must return segments with payloads; ``prefetch`` is an
    optional hint listing (piece, group) pairs about to be fetched
    (group == -1 means the piece's sign segment)."""

    def sign(self, piece: int) -> ll.Segment:
        raise NotImplementedError

    def group(self, piece: int, group: int) -> ll.Segment:
        raise NotImplementedError

    def prefetch(self, wants: List[Tuple[int, int]]) -> None:
        pass


class InlineSegmentSource(SegmentSource):
    def __init__(self, ref: Refactored):
        self._ref = ref

    def sign(self, piece: int) -> ll.Segment:
        return self._ref.pieces[piece].sign_seg

    def group(self, piece: int, group: int) -> ll.Segment:
        return self._ref.pieces[piece].groups[group]


class ProgressiveReader:
    """Stateful reader over a ``Refactored`` variable.

    ``ref`` may hold real segments (then the default inline source serves
    them) or payload-free stubs (then ``source`` must resolve the payloads,
    e.g. via a store backend).  Planning only ever touches segment *sizes*,
    so it works identically in both modes.

    ``incremental=True`` (default) routes decoding through a device-resident
    ``reconstruct.IncrementalReconstructor``: plane state never lands on
    host, new fetches cost a delta decode + partial recompose, and
    ``reconstruct_device`` serves repeats from the engine cache.
    ``incremental=False`` keeps host plane prefixes and re-decodes
    everything per call — the bit-exactness oracle."""

    def __init__(self, ref: Refactored, backend: Optional[str] = None,
                 source: Optional[SegmentSource] = None,
                 incremental: bool = True,
                 device: Optional[jax.Device] = None,
                 config: Optional["tn.RefactorConfig"] = None,
                 degrade: bool = False,
                 shared: Optional[object] = None,
                 shared_scope: Tuple[str, int] = ("", 0),
                 shared_tenant: int = 0):
        from repro import tune as tn  # local: keep import graph flat
        # config= replays a store's tuned plan (manifest VariableEntry.plan):
        # decode kernels run with the same tiling the writer used
        cfg = tn.as_config(config, backend=backend)
        self.ref = ref
        self.backend = cfg.backend
        self.config = cfg
        self.source = source if source is not None else InlineSegmentSource(ref)
        self.state = [_PieceState() for _ in ref.pieces]
        self.total_bytes_fetched = 0
        self.incremental = incremental
        # degrade=True: a plane group whose fetch fails with a typed store
        # error is dropped for the session (the piece is capped below it) and
        # the reconstruction is served WITHOUT it — the bound machinery
        # reports the honestly widened bound because planning and
        # current_bound() only ever see applied groups.  degrade=False (the
        # default) re-raises: callers that need the exact tolerance fail
        # loudly instead of silently relaxing it.
        self.degrade = degrade
        self.degraded: List[Tuple[int, int, str]] = []  # (piece, group, errtype)
        # mesh-sharded read path: pin the engine's state to the chunk's
        # owning device (core.sharded); None = uncommitted (today's path)
        self.device = device
        self.engine = (rc.IncrementalReconstructor(ref, backend=self.backend,
                                                   device=device, config=cfg)
                       if incremental else None)
        # serving-tier mode (repro.store.serving.ServingTier): plane-group
        # fetches route through a shared cross-session cache + coalescing
        # claim table, and decode jobs merge with other sessions' work.
        # Incremental-only — the oracle path stays private by construction.
        self.shared = shared if incremental else None
        self.shared_scope = tuple(shared_scope)
        self.shared_tenant = shared_tenant
        if self.engine is not None:
            self.engine.shared = self.shared

    # ----------------------------------------------------------- planning --
    def planes_kept(self) -> List[int]:
        return [sum(p.group_planes[:s.groups_fetched])
                for p, s in zip(self.ref.pieces, self.state)]

    def current_bound(self) -> float:
        return self.ref.bound(self.planes_kept())

    def floor_bound(self) -> float:
        return self.ref.bound([p.mag_bits for p in self.ref.pieces])

    # -------------------------------------------------------- degradation --
    def _limit(self, i: int) -> int:
        """Max reachable group count for piece ``i`` (cap-aware)."""
        n = len(self.ref.pieces[i].groups)
        cap = self.state[i].cap
        return n if cap is None else min(n, cap)

    @property
    def degraded_count(self) -> int:
        """Plane groups dropped by the degrade policy this session."""
        return len(self.degraded)

    def reset_degraded(self) -> None:
        """Forget degradation caps: the next fetch retries dropped groups
        (e.g. after the operator repaired the store)."""
        self.degraded.clear()
        for st in self.state:
            st.cap = None

    def plan(self, tol: float) -> List[int]:
        """Greedy (piece, group) allocation: target planes-kept per piece."""
        r = self.ref
        kept = self.planes_kept()
        groups = [s.groups_fetched for s in self.state]
        bound = r.bound(kept)
        while bound > tol:
            best, best_score = None, 0.0
            for i, pm in enumerate(r.pieces):
                gi = groups[i]
                if gi >= self._limit(i):
                    continue
                new_kept = kept[i] + pm.group_planes[gi]
                d_eps = pm.weight * (r.piece_eps(i, kept[i]) - r.piece_eps(i, new_kept))
                cost = pm.groups[gi].stored_bytes
                if gi == 0:
                    cost += pm.sign_seg.stored_bytes
                score = d_eps / max(cost, 1)
                if score > best_score:
                    best, best_score = i, score
            if best is None:
                break  # everything fetched; bound is at the floor
            bound -= r.pieces[best].weight * (
                r.piece_eps(best, kept[best])
                - r.piece_eps(best, kept[best] + r.pieces[best].group_planes[groups[best]]))
            kept[best] += r.pieces[best].group_planes[groups[best]]
            groups[best] += 1
        return groups

    # ------------------------------------------------------------ fetching --
    def pending_deltas(self, target_groups: List[int]) -> List[Tuple[int, int]]:
        """(piece, group) pairs `_fetch_to(target_groups)` would fetch; the
        sign segment of a cold piece is listed as (piece, -1)."""
        wants: List[Tuple[int, int]] = []
        for i, st in enumerate(self.state):
            tg = min(target_groups[i], self._limit(i))
            if tg <= st.groups_fetched:
                continue
            if st.groups_fetched == 0:
                wants.append((i, -1))
            wants.extend((i, g) for g in range(st.groups_fetched, tg))
        return wants

    def _fetch_to(self, target_groups: List[int],
                  degrade: Optional[bool] = None) -> int:
        """Fetch segment deltas through the source; returns bytes fetched now.

        All newly-fetched segments of the request are decoded through ONE
        batched pass (``lossless_batch.decode_segments``): same-shape
        Huffman/RLE groups — across pieces — share a single vmapped unpack
        call instead of one tiny launch per segment.  In incremental mode
        the resulting plane rows are staged on the reconstruction engine
        (device upload only — bitplane decode is deferred and batched); the
        oracle mode accumulates host plane prefixes instead.

        Byte accounting uses the sizes recorded on ``ref`` (true byte-range
        lengths for store-backed stubs), so it reflects exactly what moved
        over the backend.

        Failure policy: each segment fetch is independently guarded.  Under
        ``degrade`` (per-call override, else the reader's policy) a typed
        store failure CAPS the piece at the failed group — its prefix of
        successfully fetched groups is still applied, later groups are
        dropped, and the event is recorded in ``self.degraded``; planning
        then never asks for the capped groups again, so ``current_bound()``
        reports the honestly widened bound.  A sign-segment failure caps the
        piece at 0 (nothing decodable without signs).  Without degrade the
        error propagates and no state is mutated for the failed request."""
        from repro.store import reliability as rl  # local: store imports us
        if self.shared is not None:
            return self._fetch_to_shared(target_groups, degrade)
        deltas = self.pending_deltas(target_groups)
        self.source.prefetch(deltas)
        if degrade is None:
            degrade = self.degrade
        wants: List[Tuple[int, int, ll.Segment]] = []
        dead: set = set()  # pieces capped during THIS fetch
        for i, g in deltas:
            if i in dead:
                continue  # later groups of a capped piece are unusable
            try:
                seg = self.source.sign(i) if g < 0 else self.source.group(i, g)
            except (rl.StoreIOError, ValueError, OSError) as exc:
                if not degrade:
                    raise
                cap = 0 if g < 0 else g
                st = self.state[i]
                st.cap = cap if st.cap is None else min(st.cap, cap)
                self.degraded.append((i, g, type(exc).__name__))
                dead.add(i)
                continue
            wants.append((i, g, seg))
        blobs = lb.decode_segments([w[2] for w in wants])

        fetched = 0
        decoded: dict = {(i, g): (s, b) for (i, g, s), b in zip(wants, blobs)}
        for i, (pm, st) in enumerate(zip(self.ref.pieces, self.state)):
            tg = min(target_groups[i], self._limit(i))
            if tg <= st.groups_fetched:
                continue
            got = 0
            if st.groups_fetched == 0:
                w = pm.groups[0].meta["n_words"]
                sign = decoded[(i, -1)][1].view(np.uint32).reshape(1, w)
                if self.incremental:
                    self.engine.stage_sign(i, sign)
                else:
                    st.sign = sign
                got += pm.sign_seg.stored_bytes
            new_rows = []
            for g in range(st.groups_fetched, tg):
                seg, blob = decoded[(i, g)]
                w = seg.meta["n_words"]
                if w:
                    rows = blob.view(np.uint32).reshape(-1, w)
                else:  # empty piece: keep the (planes, 0) row structure
                    rows = np.zeros((pm.group_planes[g], 0), np.uint32)
                new_rows.append(rows)
                got += pm.groups[g].stored_bytes
            row_offset = sum(pm.group_planes[:st.groups_fetched])
            if self.incremental:
                self.engine.stage_rows(i, np.concatenate(new_rows, axis=0),
                                       row_offset)
            else:
                stack = [st.planes] if st.planes is not None else []
                st.planes = np.concatenate(stack + new_rows, axis=0)
            st.groups_fetched = tg
            st.bytes_fetched += got
            fetched += got
        self.total_bytes_fetched += fetched
        return fetched

    def _shared_job(self, i: int, g: int, seg: ll.Segment, key, fut,
                    blob: np.ndarray):
        """Package one owned plane group as a self-contained shared decode
        job (canonical row offset ``sum(group_planes[:g])``, so the decoded
        delta is session-independent and cacheable)."""
        from repro.store import serving as sv  # local: store imports us
        pm = self.ref.pieces[i]
        if g < 0:
            w = pm.groups[0].meta["n_words"]
            rows = blob.view(np.uint32).reshape(1, w)
            kind, row_offset = "sign", 0
        else:
            w = seg.meta["n_words"]
            rows = (blob.view(np.uint32).reshape(-1, w) if w
                    else np.zeros((pm.group_planes[g], 0), np.uint32))
            kind, row_offset = "group", sum(pm.group_planes[:g])
        return sv.DecodeJob(
            key=key, kind=kind, rows=rows, row_offset=row_offset, n=pm.n,
            mag_bits=self.ref.mag_bits, design=self.ref.design,
            backend=self.backend,
            tiles_per_block=self.config.tiles_per_block,
            unroll=self.config.unroll, device=self.device, future=fut)

    def _fetch_to_shared(self, target_groups: List[int],
                         degrade: Optional[bool]) -> int:
        """Serving-tier variant of ``_fetch_to``: every wanted plane group is
        CLAIMED against the shared tier first — a cache hit skips fetch and
        decode entirely, a coalesced claim waits on the owning session's
        in-flight decode (exactly one backend read + one decode per group
        service-wide), and an owned claim fetches the bytes and enqueues a
        shared decode job (deferred: merged with other sessions' jobs into
        one batched round at drain).

        Byte accounting, degrade-cap semantics, and the resulting
        reconstruction are identical to the private path: ``bytes_fetched``
        stays the logical stored size of every group APPLIED to this
        session (whether its decode ran here, elsewhere, or was cached), and
        a typed store failure — local or propagated from the owning session
        — caps the piece exactly as a direct fetch failure would."""
        from repro.store import reliability as rl  # local: store imports us
        from repro.store import serving as sv
        tier = self.shared
        if degrade is None:
            degrade = self.degrade
        deltas = self.pending_deltas(target_groups)
        if not deltas:
            return 0
        r = self.ref
        # empty pieces decode to nothing (private staging drops them too):
        # keep them out of the tier, account their logical bytes below
        claimable = [(i, g) for i, g in deltas if r.pieces[i].n > 0]
        keys = {d: self.shared_scope + d for d in claimable}
        claims = tier.claim(self.shared_tenant,
                            [keys[d] for d in claimable])
        mine = [d for d in claimable if claims[keys[d]][0] == "mine"]
        # byte-range prefetch only what THIS session will read: coalesced
        # groups are fetched (once) by their owning session
        self.source.prefetch(mine)

        results: dict = {}
        dead: dict = {}  # piece -> the exception that capped it (this call)

        def _cap(i: int, g: int, exc: BaseException) -> None:
            st = self.state[i]
            cap = 0 if g < 0 else g
            st.cap = cap if st.cap is None else min(st.cap, cap)
            self.degraded.append((i, g, type(exc).__name__))
            dead[i] = exc

        # -- phase 1: owned claims — fetch + lossless decode + submit.
        # Every owned key resolves exactly one way (submit / fail /
        # abandon), so a coalesced waiter can never hang on this session.
        wants: List[Tuple[int, int, ll.Segment, object]] = []
        try:
            for (i, g) in claimable:
                kind, payload = claims[keys[(i, g)]]
                if kind != "mine":
                    continue
                if i in dead:
                    # an earlier group of this piece already failed: these
                    # bytes were never read and this session will never use
                    # them — propagate the piece's fault to any coalesced
                    # waiters (never cached: their next request retries)
                    tier.fail(keys[(i, g)], dead[i])
                    continue
                try:
                    seg = (self.source.sign(i) if g < 0
                           else self.source.group(i, g))
                except (rl.StoreIOError, ValueError, OSError) as exc:
                    tier.fail(keys[(i, g)], exc)
                    if not degrade:
                        raise
                    _cap(i, g, exc)
                    continue
                wants.append((i, g, seg, payload))
            blobs = lb.decode_segments([w[2] for w in wants])
            tier.submit(self.shared_tenant,
                        [self._shared_job(i, g, seg, keys[(i, g)], fut, blob)
                         for (i, g, seg, fut), blob in zip(wants, blobs)])
        except BaseException as exc:
            tier.abandon(self.shared_tenant, [keys[d] for d in mine], exc)
            raise
        for (i, g, _, fut) in wants:
            results[(i, g)] = ("future", fut)

        # -- phase 2a: coalesced claims — resolve ALL waits before touching
        # any state (non-degrade contract: a failed request mutates
        # nothing).  wait_for pumps the shared queue, so two sessions
        # blocked on each other's claims decode each other's jobs.
        for (i, g) in claimable:
            kind, payload = claims[keys[(i, g)]]
            if kind == "hit":
                results[(i, g)] = ("value", payload)
            elif kind == "theirs":
                if i in dead:
                    continue
                try:
                    v = tier.wait_for(payload)
                except (rl.StoreIOError, ValueError, OSError) as exc:
                    if not degrade:
                        raise
                    _cap(i, g, exc)
                    continue
                results[(i, g)] = ("value", v)

        # -- phase 2b: stage + account exactly as the private path.  Cache
        # hits and resolved waits stage as pre-resolved futures, owned jobs
        # as live ones; the tier OR-applies all of them at drain time.
        fetched = 0
        for i, (pm, st) in enumerate(zip(r.pieces, self.state)):
            tg = min(target_groups[i], self._limit(i))
            if tg <= st.groups_fetched:
                continue
            got = 0
            if st.groups_fetched == 0:
                if pm.n > 0:
                    self.engine.stage_shared(
                        "sign", i, sv.entry_future(results[(i, -1)]))
                got += pm.sign_seg.stored_bytes
            for g in range(st.groups_fetched, tg):
                if pm.n > 0:
                    self.engine.stage_shared(
                        "group", i, sv.entry_future(results[(i, g)]))
                got += pm.groups[g].stored_bytes
            st.groups_fetched = tg
            st.bytes_fetched += got
            fetched += got
        self.total_bytes_fetched += fetched
        return fetched

    def peek_best(self) -> Tuple[float, Optional[int]]:
        """(score, piece) of the single best next merged group by
        error-reduction-per-byte, or (-1.0, None) if everything is fetched."""
        r = self.ref
        kept = self.planes_kept()
        best, best_score = None, -1.0
        for i, pm in enumerate(r.pieces):
            gi = self.state[i].groups_fetched
            if gi >= self._limit(i) or pm.n == 0:
                continue
            new_kept = kept[i] + pm.group_planes[gi]
            d_eps = pm.weight * (r.piece_eps(i, kept[i]) - r.piece_eps(i, new_kept))
            cost = pm.groups[gi].stored_bytes
            if gi == 0:
                cost += pm.sign_seg.stored_bytes
            score = d_eps / max(cost, 1)
            if score > best_score:
                best, best_score = i, score
        return best_score, best

    def fetch_one_more_group(self) -> int:
        """MA primitive: fetch the single best next merged group (greedy by
        error-reduction-per-byte) — the finest augmentation granularity."""
        _, best = self.peek_best()
        if best is None:
            return 0
        target = [s.groups_fetched for s in self.state]
        target[best] += 1
        return self._fetch_to(target)

    # -------------------------------------------------------- reconstruction --
    def _reconstruct_full_device(self) -> jax.Array:
        """Oracle path: re-decode every fetched piece from its host plane
        prefix and recompose from scratch (no state reuse)."""
        r = self.ref
        pieces_dec = []
        for pm, st in zip(r.pieces, self.state):
            p_kept = sum(pm.group_planes[:st.groups_fetched])
            if p_kept == 0 or pm.n == 0:
                pieces_dec.append(jnp.zeros((pm.n,), jnp.float32))
                continue
            mag = kops.decode_bitplanes(
                jnp.asarray(st.planes), r.mag_bits, pm.n, r.design,
                backend=self.backend,
                tiles_per_block=self.config.tiles_per_block,
                unroll=self.config.unroll)
            sign = kops.decode_bitplanes(
                jnp.asarray(st.sign), 1, pm.n, r.design,
                backend=self.backend,
                tiles_per_block=self.config.tiles_per_block,
                unroll=self.config.unroll)
            x = al.align_decode(mag, sign, jnp.int32(pm.exponent),
                                r.mag_bits, planes_kept=p_kept)
            pieces_dec.append(x)
        return dc.recompose(pieces_dec, r.shape, r.levels)

    def reconstruct_device(self) -> Tuple[jax.Array, float]:
        """Decode current state -> (device array, max-norm error bound).

        Incremental mode costs only the staged delta decode + recompose
        suffix (engine-cached when nothing changed); the result stays on
        device — no host sync on this path."""
        if self.incremental:
            out = self.engine.reconstruct_device()
        else:
            out = self._reconstruct_full_device()
        return out, self.current_bound()

    def reconstruct(self) -> Tuple[np.ndarray, float]:
        """Decode current state -> (host array, guaranteed max-norm bound)."""
        x, bound = self.reconstruct_device()
        return np.asarray(x), bound

    def delta_decoded_bytes(self) -> int:
        """Delta plane bytes this reader's engine has actually decoded
        (0 in oracle mode — there is no delta path to account)."""
        return self.engine.bytes_decoded if self.incremental else 0

    def decoded_plane_bytes(self) -> int:
        """Plane + sign bytes a from-scratch decode of the current state runs
        through the bitplane decoder — the full-decode baseline that the
        engine's delta accounting (``delta_decoded_bytes``) is measured
        against."""
        total = 0
        for pm, st in zip(self.ref.pieces, self.state):
            if pm.n == 0 or st.groups_fetched == 0:
                continue
            w = kref.padded_words(pm.n, self.ref.design)
            kept = sum(pm.group_planes[:st.groups_fetched])
            total += 4 * w * (kept + 1)  # +1: the sign plane
        return total

    def stage_retrieve(self, tol: float, relative: bool = False) -> int:
        """Plan + fetch + stage WITHOUT reconstructing; returns bytes fetched.

        In incremental mode the newly-fetched plane groups land *staged* on
        the engine (device upload only — the delta bitplane decode is
        deferred), so many readers' staged groups can be drained in one
        per-device batched pass (``sharded.ShardedReconstructEngine.drain``
        over ``reconstruct.batch_apply_pending``) before each reader's
        ``reconstruct_device``.  The chunked reconstruct pipeline uses this
        split to decode a whole in-flight window of chunks per launch batch
        instead of one chunk at a time.  Oracle (non-incremental) mode
        materializes host planes at fetch time, so staging is simply the
        fetch."""
        if relative:
            tol = tol * self.ref.data_range
        return self._fetch_to(self.plan(tol))

    def retrieve_device(self, tol: float, relative: bool = False
                        ) -> Tuple[jax.Array, float, int]:
        """``retrieve`` with the reconstruction left on device."""
        fetched = self.stage_retrieve(tol, relative=relative)
        x, bound = self.reconstruct_device()
        return x, bound, fetched

    def retrieve(self, tol: float, relative: bool = False) -> Tuple[np.ndarray, float, int]:
        """Progressively retrieve to |x - x_hat|_inf <= tol.

        Returns (x_hat, achieved_bound, bytes_fetched_this_call)."""
        x, bound, fetched = self.retrieve_device(tol, relative=relative)
        return np.asarray(x), bound, fetched
