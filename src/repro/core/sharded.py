"""Mesh-sharded execution layer for the refactor and retrieval workflows.

HP-MDR targets multi-GPU nodes, and the scalable multigrid refactoring
line of work shows refactoring scales near-linearly when each device owns
a shard of the domain: chunks are independent (each is refactored with its
own decomposition, alignment, and lossless state), so the natural data
axis is the *chunk* axis.  This module owns the chunk -> device placement
policy and the per-device execution of the existing single-device engines:

``ShardedRefactorPlan`` (write side)
    Splits a variable's chunks round-robin across the devices of a 1-d
    ``'chunk'`` mesh (``make_chunk_mesh``; any ``Mesh`` is accepted — its
    device array is flattened into chunk-axis order).  Each chunk's whole
    encode chain still runs through the cached one-dispatch program of
    ``refactor_fused.fused_encode_plan``; committing the chunk's input to
    its owning device (``jax.device_put``) makes the jitted program execute
    there, so every device holds its own queue of collective-free
    dispatches, all in flight concurrently (``dispatch_ahead`` deep per
    device under the chunked pipeline).  ``finish_many`` resolves ANY
    batch of dispatched chunks — a full per-device window, not one round —
    with one ``lossless_batch.host_sync`` for the batch's tiny scalar
    metadata (per-piece exponents, amax, range) plus one stacked codec
    pass (``refactor_fused.finish_encode_many``): the amortized scalar
    gather count per chunk is ``1 / batch`` (< 1 whenever two or more
    chunks are in flight).

``ShardedReconstructEngine`` (read side)
    Places each chunk's incremental reconstruction state
    (``reconstruct.IncrementalReconstructor``) on the chunk's owning
    device and drains staged plane groups with per-device
    ``reconstruct.batch_apply_pending`` — decode buckets never mix
    devices, so every delta decode runs where its engine state lives.

Bit-exactness contract: placement never changes values.  A mesh of one
device is exactly today's path (same jitted programs, same device), and a
mesh of N host devices compiles the *same jaxpr* per device, so the
serialized output is byte-identical to the single-device oracle regardless
of device count — property-tested in tests/test_sharded.py and enforced
end-to-end by the store oracle test (single-device vs sharded writer
producing byte-identical segment files).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.core import lossless as ll
from repro.core import lossless_batch as lb
from repro.core import reconstruct as rc
from repro.core import refactor as rf
from repro.core import refactor_fused as rff
from repro.obs import trace as obs_trace
from repro import tune as tn

try:  # jax >= 0.4: canonical home of Mesh
    from jax.sharding import Mesh
except ImportError:  # pragma: no cover - ancient jax
    from jax.interpreters.pxla import Mesh  # type: ignore

MeshLike = Union[None, int, Mesh]

CHUNK_AXIS = "chunk"


# ------------------------------------------------------------------- stats --

@dataclasses.dataclass
class ShardedStats:
    """Counters for the sharded layer (thread-safe, process-global).

    ``dispatches_by_device`` maps device ordinal (position in the chunk-axis
    device order) to fused dispatches issued there — round-robin placement
    shows up as a flat histogram.  ``rounds`` counts batched finishes (one
    cross-device scalar gather each); ``chunks_finished`` the chunks they
    resolved — their ratio is the amortized scalar-gathers-per-chunk number
    the async scheduler drives below 1 (counter-tested in
    tests/test_sharded.py)."""
    rounds: int = 0
    drains: int = 0
    chunks_finished: int = 0

    def __post_init__(self):
        self._lock = threading.Lock()
        self.dispatches_by_device: Dict[int, int] = {}

    def add_dispatch(self, ordinal: int) -> None:
        with self._lock:
            self.dispatches_by_device[ordinal] = (
                self.dispatches_by_device.get(ordinal, 0) + 1)

    def add(self, **kw: int) -> None:
        with self._lock:
            for k, v in kw.items():
                setattr(self, k, getattr(self, k) + v)

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {"rounds": self.rounds, "drains": self.drains,
                    "chunks_finished": self.chunks_finished,
                    "dispatches_by_device": dict(self.dispatches_by_device)}

    def reset(self) -> None:
        with self._lock:
            self.rounds = 0
            self.drains = 0
            self.chunks_finished = 0
            self.dispatches_by_device = {}


STATS = ShardedStats()


# -------------------------------------------------------------------- mesh --

def make_chunk_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-d ``('chunk',)`` mesh over the first ``n_devices`` local devices.

    ``None`` takes every available device.  This is the write/read stack's
    data axis: chunk ``ci`` lives on device ``ci % n`` of this mesh."""
    devs = jax.devices()
    if n_devices is not None:
        if n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {n_devices}")
        if n_devices > len(devs):
            raise ValueError(
                f"requested {n_devices} devices, only {len(devs)} available")
        devs = devs[:n_devices]
    return Mesh(np.array(devs), (CHUNK_AXIS,))


def resolve_mesh(mesh: MeshLike) -> Optional[Mesh]:
    """Normalize the ``mesh=`` knob: None / device count / ``Mesh``."""
    if mesh is None or isinstance(mesh, Mesh):
        return mesh
    if isinstance(mesh, int):
        return make_chunk_mesh(mesh)
    raise TypeError(f"mesh must be None, an int, or a Mesh, got {type(mesh)!r}")


def chunk_devices(mesh: Optional[Mesh]) -> List[Optional[jax.Device]]:
    """Chunk-axis device order of ``mesh`` (flattened for multi-axis meshes).

    ``None`` mesh -> ``[None]``: a single *uncommitted* slot, so the
    single-device path stays exactly today's ``jax.device_put(x)``."""
    if mesh is None:
        return [None]
    return list(mesh.devices.reshape(-1))


def _put(x, device: Optional[jax.Device]):
    """``device_put`` to a committed device, or today's uncommitted put."""
    return jax.device_put(x) if device is None else jax.device_put(x, device)


# -------------------------------------------------------------- write side --

class ShardedRefactorPlan:
    """Chunk -> device placement + per-shard fused dispatch (write side).

    Stateless apart from counters: ``place``/``dispatch`` may be called from
    any thread (the chunked pipeline's prefetcher places, the main thread
    dispatches).  All chunks of one variable share the cached
    ``fused_encode_plan`` programs — each device compiles the same jaxpr, so
    outputs are bitwise independent of placement."""

    def __init__(self, mesh: MeshLike,
                 levels: Optional[int] = None,
                 design: Optional[str] = None,
                 mag_bits: Optional[int] = None,
                 hybrid: Optional[ll.HybridConfig] = None,
                 backend: Optional[str] = None,
                 config: Optional[tn.RefactorConfig] = None):
        force = hybrid.force if hybrid is not None else None
        cfg = tn.as_config(config, design=design, mag_bits=mag_bits,
                           hybrid=hybrid, backend=backend)
        self.config = cfg
        self.mesh = resolve_mesh(mesh if mesh is not None
                                 else cfg.mesh_devices)
        self.devices = chunk_devices(self.mesh)
        self.levels = levels
        self.design = cfg.design
        self.mag_bits = cfg.mag_bits
        self.hybrid = cfg.hybrid(force=force)
        self.backend = cfg.backend

    @property
    def n_shards(self) -> int:
        return len(self.devices)

    def shard_for(self, ci: int) -> int:
        """Round-robin chunk -> shard ordinal (the manifest's record)."""
        return ci % self.n_shards

    def device_for(self, ci: int) -> Optional[jax.Device]:
        return self.devices[self.shard_for(ci)]

    def place(self, ci: int, host_chunk) -> jax.Array:
        """Commit chunk ``ci``'s input to its owning device (H2D copy)."""
        obs_trace.event(obs_trace.EV_DEVICE_PUT, chunk=ci,
                        device=self.shard_for(ci))
        return _put(host_chunk, self.device_for(ci))

    def dispatch(self, ci: int, chunk, name: str = "var",
                 donate: bool = False) -> rff.PendingChunk:
        """One collective-free fused dispatch on chunk ``ci``'s device.

        ``chunk`` may be a host array (placed here) or an already-placed
        device array from ``place``.  ``donate=True`` forwards the encode
        input for buffer donation (``refactor_fused.dispatch_encode``) —
        only pass it for buffers this layer's caller owns exclusively, e.g.
        the pipeline's placed copies.  Under tracing the span carries the
        owning device ordinal, so the Chrome-trace export renders one track
        per device (queue-drain idle gaps become visible)."""
        if not isinstance(chunk, jax.Array):
            chunk = self.place(ci, chunk)
        STATS.add_dispatch(self.shard_for(ci))
        with obs_trace.span("sharded.dispatch", chunk=ci,
                            device=self.shard_for(ci)):
            return rff.dispatch_encode(chunk, name=name, levels=self.levels,
                                       hybrid=self.hybrid, config=self.config,
                                       donate=donate)

    def dispatch_round(self, chunks: Sequence[Tuple[int, np.ndarray]],
                       name: str = "var") -> List[rff.PendingChunk]:
        """Dispatch one round: each (ci, host_chunk) to its owning device.

        Dispatches are async and collective-free, so a round of N chunks on
        N devices runs concurrently — the multi-device analogue of the
        single-device dispatch-ahead window."""
        return [self.dispatch(ci, chunk, name=f"{name}.{ci}")
                for ci, chunk in chunks]

    def finish_many(self, pendings: Sequence[rff.PendingChunk]
                    ) -> List[rf.Refactored]:
        """Resolve a batch of dispatched chunks — any number, any device mix:
        ONE host sync gathers every chunk's scalar metadata (exponents /
        amax / range) across devices, and ONE stacked lossless pass encodes
        every chunk's blob rows (``refactor_fused.finish_encode_many``), so
        a batch of B chunks costs 3 host syncs — not 3B.  Results come back
        in input order, byte-identical to finishing chunk by chunk."""
        pendings = list(pendings)
        if not pendings:
            return []
        STATS.add(rounds=1, chunks_finished=len(pendings))
        with obs_trace.span("sharded.finish_many", chunks=len(pendings)):
            return rff.finish_encode_many(pendings)

    def finish_round(self, pendings: Sequence[rff.PendingChunk]
                     ) -> List[rf.Refactored]:
        """Back-compat alias: a round is just a batch of one chunk per
        device — ``finish_many`` handles any batch shape."""
        return self.finish_many(pendings)

    def refactor_chunks(self, chunks: Sequence[np.ndarray], name: str = "var"
                        ) -> List[rf.Refactored]:
        """Convenience: dispatch up to one window (one chunk per device)
        ahead, finishing each window with one batched gather, returning
        results in chunk order."""
        out: List[rf.Refactored] = []
        n = self.n_shards
        for base in range(0, len(chunks), n):
            rnd = [(base + j, c)
                   for j, c in enumerate(chunks[base:base + n])]
            out.extend(self.finish_many(self.dispatch_round(rnd, name=name)))
        return out


# --------------------------------------------------------------- read side --

class ShardedReconstructEngine:
    """Chunk -> device placement for incremental reconstruction state.

    ``engine_for`` builds a ``reconstruct.IncrementalReconstructor`` pinned
    to the chunk's owning device; ``drain`` decodes the staged plane groups
    of many engines with one ``batch_apply_pending`` pass *per device*, so
    decode buckets never mix devices and every kernel launch runs where its
    engine state lives.  ``shards`` (the manifest's recorded chunk -> shard
    map) overrides round-robin placement, taken modulo the mesh size so a
    store written on N devices reads fine on M."""

    def __init__(self, mesh: MeshLike,
                 shards: Optional[Sequence[int]] = None):
        self.mesh = resolve_mesh(mesh)
        self.devices = chunk_devices(self.mesh)
        self.shards = list(shards) if shards is not None else None

    @property
    def n_shards(self) -> int:
        return len(self.devices)

    def shard_for(self, ci: int) -> int:
        if self.shards is not None and ci < len(self.shards):
            return self.shards[ci] % self.n_shards
        return ci % self.n_shards

    def device_for(self, ci: int) -> Optional[jax.Device]:
        return self.devices[self.shard_for(ci)]

    def engine_for(self, ci: int, ref: rf.Refactored, backend: str = "auto"
                   ) -> rc.IncrementalReconstructor:
        return rc.IncrementalReconstructor(ref, backend=backend,
                                           device=self.device_for(ci))

    @staticmethod
    def drain(engines: Sequence[rc.IncrementalReconstructor]) -> None:
        """Decode many engines' staged plane groups, per device.

        ``reconstruct.batch_apply_pending``'s bucket key includes each
        engine's owning device, so one call already yields per-device
        decode batches — shards never mix in a stacked launch, and every
        kernel runs where its engine state lives."""
        rc.batch_apply_pending(list(engines))
        STATS.add(drains=1)
