"""Lossless encoding of packed bitplane groups (paper §5).

Three codecs + the Algorithm-2 hybrid selector:

* **Huffman** — canonical, length-limited (<=16 bit codes, zlib-style Kraft
  fixup).  Encode is the GPU-parallel formulation: per-symbol code lengths ->
  prefix-sum bit offsets -> two disjoint scatter-ORs into the packed word
  stream.  Decode is chunk-parallel (the standard GPU decoder structure):
  bit offsets of every CHUNK-th symbol are stored in the segment header, each
  chunk is decoded independently with a 2^16 peek-LUT inside a lax.scan, and
  chunks are vmapped.
* **RLE** — scan-based: run breaks via neighbor comparison (+ forced breaks
  every 32768 symbols so lengths fit uint16), run starts via scatter-min,
  decode via cumsum + searchsorted (fully parallel).
* **DC** — direct copy.

CR estimators (paper §5.2): Huffman cost is the exact canonical-codebook bit
cost from the histogram (the histogram is reused by the encoder, so the
estimate is nearly free); RLE cost is 3 bytes/run from the run-break count.

The hybrid selector is Algorithm 2 verbatim: groups of ``m`` planes, size
threshold ``T_s``, CR threshold ``T_cr``, Huffman-priority ordering.
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
import struct
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

CHUNK = 4096          # symbols per parallel-decode chunk
MAX_CODE_LEN = 16     # length-limited canonical Huffman
RLE_BREAK = 32768     # forced run break so lengths fit in uint16

# Bit offsets inside _huffman_pack/_huffman_unpack are uint32; a group whose
# packed stream could reach 2**32 bits would silently wrap the cumsum, so
# groups are capped at the largest symbol count that cannot overflow even if
# every symbol takes the maximum code length (~2.7e8 symbols; a merged plane
# group of a sanely-chunked array is orders of magnitude below this).
MAX_GROUP_SYMS = ((1 << 32) - 1) // MAX_CODE_LEN


def _check_group_size(n: int) -> None:
    if n > MAX_GROUP_SYMS:
        raise ValueError(
            f"group of {n} symbols exceeds MAX_GROUP_SYMS={MAX_GROUP_SYMS} "
            "(uint32 bit offsets would overflow); use smaller chunks")


# ---------------------------------------------------------------- codebook --

def build_codebook(hist: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Canonical, length-limited Huffman codebook from a 256-bin histogram.

    Returns (lengths uint8[256], codes uint32[256]); absent symbols get len 0.

    Two-queue Huffman (one sort, then O(n) merges) instead of a heap — the
    codebook build sits on the per-chunk write path (one per huffman group),
    and the heap formulation was the single hottest host-side item there.
    Output is bit-identical to ``_build_codebook_ref`` (the retired heap
    build, kept as the property-test oracle): the heap pops min ``(freq,
    idx)`` where leaves carry idx < 256 and internal nodes idx >= 256 in
    creation order, so a freq tie always resolves leaf-first and, between
    internal nodes, in FIFO creation order — exactly what popping from a
    (freq, symbol)-sorted leaf queue and a FIFO internal queue reproduces
    (internal freqs are non-decreasing in creation order, the classic
    two-queue invariant).
    """
    hist = np.asarray(hist, dtype=np.int64)
    present = np.nonzero(hist)[0]
    lengths = np.zeros(256, dtype=np.uint8)
    if len(present) == 0:
        return lengths, np.zeros(256, dtype=np.uint32)
    if len(present) == 1:
        lengths[present[0]] = 1
    else:
        freqs = hist[present]
        order = np.argsort(freqs, kind="stable")  # (freq, symbol) ascending
        lf = freqs[order].tolist()
        n_leaves = len(lf)
        leaf_sym = present[order].tolist()
        qf: List[int] = []          # internal-node freqs (non-decreasing)
        kids: List[Tuple[int, int]] = []
        li = qi = nq = 0
        # inlined two-queue pops (this loop runs ~2x255 times per group on
        # the write hot path): node id is the leaf symbol (< 256) or 256 +
        # internal creation index; <= prefers the leaf on a freq tie (leaf
        # id < internal id, matching the heap's (freq, idx) order)
        for _ in range(n_leaves - 1):
            if qi >= nq or (li < n_leaves and lf[li] <= qf[qi]):
                f1, i1 = lf[li], leaf_sym[li]; li += 1
            else:
                f1, i1 = qf[qi], 256 + qi; qi += 1
            if qi >= nq or (li < n_leaves and lf[li] <= qf[qi]):
                f2, i2 = lf[li], leaf_sym[li]; li += 1
            else:
                f2, i2 = qf[qi], 256 + qi; qi += 1
            qf.append(f1 + f2)
            kids.append((i1, i2))
            nq += 1
        # depths top-down: children are created strictly before their parent,
        # so a reverse pass sees every parent's depth before its children's
        depth = [0] * len(kids)
        for k in range(len(kids) - 1, -1, -1):
            d = depth[k] + 1
            for c in kids[k]:
                if c < 256:
                    lengths[c] = d
                else:
                    depth[c - 256] = d
        # length-limit + Kraft fixup
        lengths[present] = np.minimum(lengths[present], MAX_CODE_LEN)
        def kraft() -> int:
            return int(np.sum(1 << (MAX_CODE_LEN - lengths[present].astype(np.int64))))
        cap = 1 << MAX_CODE_LEN
        while kraft() > cap:
            # lengthen the currently-longest shortenable code (min freq impact)
            cand = present[lengths[present] < MAX_CODE_LEN]
            i = cand[np.argmax(lengths[cand])]
            lengths[i] += 1
    # canonical code assignment in (length, symbol) order, vectorized via the
    # standard next_code recurrence: code(s) = next_code[len(s)] + rank of s
    # among same-length symbols — identical to the sequential shift-and-
    # increment walk (``_build_codebook_ref``)
    codes = np.zeros(256, dtype=np.uint32)
    plens = lengths[present].astype(np.int64)
    bl_count = np.bincount(plens, minlength=MAX_CODE_LEN + 1)
    next_code = np.zeros(MAX_CODE_LEN + 1, dtype=np.int64)
    code = 0
    for l in range(1, MAX_CODE_LEN + 1):
        code = (code + int(bl_count[l - 1])) << 1
        next_code[l] = code
    corder = np.argsort(plens, kind="stable")  # present ascending -> (len, sym)
    sl = plens[corder]
    rank = np.arange(len(sl)) - np.searchsorted(sl, sl)
    codes[present[corder]] = (next_code[sl] + rank).astype(np.uint32)
    return lengths, codes


def _build_codebook_ref(hist: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Reference heap-built codebook (the pre-optimization implementation).

    Kept ONLY as the property-test oracle for ``build_codebook``: the stored
    format depends on the exact code lengths, so the fast build must stay
    bit-identical to this forever."""
    hist = np.asarray(hist, dtype=np.int64)
    present = np.nonzero(hist)[0]
    lengths = np.zeros(256, dtype=np.uint8)
    if len(present) == 0:
        return lengths, np.zeros(256, dtype=np.uint32)
    if len(present) == 1:
        lengths[present[0]] = 1
    else:
        heap = [(int(hist[s]), int(s), None) for s in present]
        counter = 256
        heapq.heapify(heap)
        parents: Dict[int, Tuple[int, int]] = {}
        while len(heap) > 1:
            f1, i1, _ = heapq.heappop(heap)
            f2, i2, _ = heapq.heappop(heap)
            parents[counter] = (i1, i2)
            heapq.heappush(heap, (f1 + f2, counter, None))
            counter += 1
        root = heap[0][1]
        stack = [(root, 0)]
        while stack:
            node, d = stack.pop()
            if node < 256:
                lengths[node] = max(d, 1)
            else:
                l, r = parents[node]
                stack.append((l, d + 1))
                stack.append((r, d + 1))
        lengths[present] = np.minimum(lengths[present], MAX_CODE_LEN)
        def kraft() -> int:
            return int(np.sum(1 << (MAX_CODE_LEN - lengths[present].astype(np.int64))))
        cap = 1 << MAX_CODE_LEN
        while kraft() > cap:
            cand = present[lengths[present] < MAX_CODE_LEN]
            i = cand[np.argmax(lengths[cand])]
            lengths[i] += 1
    codes = np.zeros(256, dtype=np.uint32)
    order = sorted(present, key=lambda s: (lengths[s], s))
    code = 0
    prev_len = lengths[order[0]]
    for s in order:
        code <<= int(lengths[s]) - int(prev_len)
        codes[s] = code
        code += 1
        prev_len = lengths[s]
    return lengths, codes


def _build_decode_lut(lengths: np.ndarray, codes: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """2^16-entry peek LUT: top-16-bit window -> (symbol, code length)."""
    lut_sym = np.zeros(1 << MAX_CODE_LEN, dtype=np.uint8)
    lut_len = np.zeros(1 << MAX_CODE_LEN, dtype=np.uint8)
    for s in range(256):
        l = int(lengths[s])
        if l == 0:
            continue
        base = int(codes[s]) << (MAX_CODE_LEN - l)
        span = 1 << (MAX_CODE_LEN - l)
        lut_sym[base:base + span] = s
        lut_len[base:base + span] = l
    return lut_sym, lut_len


# ------------------------------------------------------------------ encode --

@functools.partial(jax.jit, static_argnames=())
def _huffman_pack(syms: jax.Array, lens_tab: jax.Array, codes_tab: jax.Array):
    """Parallel bit-pack: returns (words uint32[cap], total_bits, chunk_offs)."""
    syms = syms.astype(jnp.int32)
    lens = lens_tab[syms].astype(jnp.uint32)
    codes = codes_tab[syms].astype(jnp.uint32)
    offs_incl = jnp.cumsum(lens, dtype=jnp.uint32)
    offs = offs_incl - lens  # exclusive
    total_bits = offs_incl[-1] if syms.shape[0] else jnp.uint32(0)
    cap = syms.shape[0] * MAX_CODE_LEN // 32 + 2
    codes_msb = codes << (jnp.uint32(32) - lens)
    w = (offs >> jnp.uint32(5)).astype(jnp.int32)
    sh = offs & jnp.uint32(31)
    lo = codes_msb >> sh
    spill = jnp.where(sh > 0, codes_msb << (jnp.uint32(32) - sh), jnp.uint32(0))
    words = jnp.zeros((cap,), jnp.uint32)
    words = words.at[w].add(lo, mode="drop")
    words = words.at[w + 1].add(spill, mode="drop")
    chunk_offs = offs[::CHUNK]
    return words, total_bits, chunk_offs


@functools.partial(jax.jit, static_argnames=("n_syms",))
def _huffman_unpack(words: jax.Array, chunk_offs: jax.Array,
                    lut_sym: jax.Array, lut_len: jax.Array, n_syms: int):
    """Chunk-parallel decode: scan within chunk, vmap over chunks."""
    words = jnp.concatenate([words, jnp.zeros((2,), jnp.uint32)])

    def peek(p):
        wi = (p >> jnp.uint32(5)).astype(jnp.int32)
        sh = p & jnp.uint32(31)
        hi = words[wi]
        lo = words[wi + 1]
        win = (hi << sh) | jnp.where(sh > 0, lo >> (jnp.uint32(32) - sh), jnp.uint32(0))
        return win >> jnp.uint32(32 - MAX_CODE_LEN)

    def chunk_decode(start_bit):
        def step(p, _):
            idx = peek(p).astype(jnp.int32)
            sym = lut_sym[idx]
            l = lut_len[idx].astype(jnp.uint32)
            return p + l, sym
        _, syms = jax.lax.scan(step, start_bit, None, length=CHUNK)
        return syms

    out = jax.vmap(chunk_decode)(chunk_offs.astype(jnp.uint32))
    return out.reshape(-1)[:n_syms]


@jax.jit
def _rle_scan(syms: jax.Array):
    n = syms.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    prev = jnp.concatenate([syms[:1] ^ jnp.uint8(255), syms[:-1]])
    brk = (syms != prev) | (idx % RLE_BREAK == 0)
    run_id = jnp.cumsum(brk.astype(jnp.int32)) - 1
    nruns = run_id[-1] + 1
    starts = jnp.full((n,), n, jnp.int32).at[run_id].min(idx)
    values = syms[jnp.clip(starts, 0, n - 1)]
    ends = jnp.concatenate([starts[1:], jnp.full((1,), n, jnp.int32)])
    lengths = ends - starts
    return values, lengths, nruns


@functools.partial(jax.jit, static_argnames=("n",))
def _rle_expand(values: jax.Array, lengths: jax.Array, n: int):
    cum = jnp.cumsum(lengths.astype(jnp.int32))
    idx = jnp.searchsorted(cum, jnp.arange(n, dtype=jnp.int32), side="right")
    return values[idx]


# -------------------------------------------------------------- estimators --

def estimate_huffman(hist: np.ndarray, n: int) -> Tuple[float, np.ndarray, np.ndarray]:
    """Exact canonical-codebook cost estimate (paper: build tree, sum f*len).

    Returns (CR, lengths, codes) so the encoder can reuse the codebook."""
    lengths, codes = build_codebook(hist)
    bits = int(np.sum(hist * lengths.astype(np.int64)))
    overhead = 256 + 4 * (n // CHUNK + 1) + 16
    bytes_est = bits / 8.0 + overhead
    return (n / bytes_est if bytes_est else 1.0), lengths, codes


def estimate_rle(n_runs: int, n: int) -> float:
    bytes_est = 3.0 * n_runs + 16
    return n / bytes_est if bytes_est else 1.0


def exact_stored_bytes(method: str, n: int, total_bits: int = 0,
                       n_runs: int = 0) -> int:
    """EXACT ``len(Segment.to_bytes())`` of a group, computed BEFORE
    encoding from selection-time stats (hist-derived ``total_bits`` for
    huffman, ``n_runs`` for rle).

    This is what the Algorithm-2 store-raw fallback compares: the CR
    estimators above use the paper's approximate overhead constants, so near
    the break-even point a "winning" codec can still serialize larger than
    the raw bytes.  Constants are derived from ``Segment.to_bytes`` framing
    (header 16 + meta count 4; meta entry 4+len(key)+8; payload entry
    4+len(key)+5+data) and property-tested against real serializations in
    tests/test_tune.py.  Meta entries callers add after encoding
    (``n_planes``/``n_words``) are identical across methods and cancel."""
    if method == "dc":        # meta n_syms; payload raw[n]
        return 50 + n
    if method == "huffman":   # meta n_syms,total_bits; chunk_offs,lengths,words
        n_words = (total_bits + 31) // 32 + 1
        return 361 + 4 * n_words + 4 * ((n + CHUNK - 1) // CHUNK + 1)
    if method == "rle":       # meta n_syms; values[r] u8, lengths[r] u16
        return 69 + 3 * n_runs
    raise ValueError(f"unknown method {method!r}")


# ---------------------------------------------------------------- segments --

_METHODS = {"dc": 0, "huffman": 1, "rle": 2, "empty": 3}
_METHOD_NAMES = {v: k for k, v in _METHODS.items()}
_MAGIC = 0x4D445253  # 'MDRS'


@dataclasses.dataclass
class Segment:
    """One losslessly-encoded unit (a merged bitplane group).

    A Segment may be a payload-free *stub*: metadata only, with the true
    serialized size recorded in ``meta["stored_bytes"]``.  Stubs are what a
    store manifest materializes so the retrieval planner can cost byte ranges
    without ever touching segment payloads (see repro.store.layout).
    """
    method: str
    n_bytes: int                      # original (uncompressed) byte count
    payload: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    meta: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def stored_bytes(self) -> int:
        if "stored_bytes" in self.meta:
            return int(self.meta["stored_bytes"])
        return sum(a.nbytes for a in self.payload.values()) + 64

    @property
    def is_stub(self) -> bool:
        return not self.payload and "stored_bytes" in self.meta

    def to_bytes(self) -> bytes:
        parts = [struct.pack("<IIIi", _MAGIC, _METHODS[self.method],
                             self.n_bytes, len(self.payload))]
        parts.append(struct.pack("<i", len(self.meta)))
        for k, v in sorted(self.meta.items()):
            kb = k.encode()
            parts.append(struct.pack("<i", len(kb)) + kb + struct.pack("<q", v))
        for k, a in sorted(self.payload.items()):
            kb = k.encode()
            a = np.ascontiguousarray(a)
            parts.append(struct.pack("<i", len(kb)) + kb)
            parts.append(struct.pack("<ci", a.dtype.char.encode(), a.size))
            parts.append(a.tobytes())
        return b"".join(parts)

    @staticmethod
    def from_bytes(buf: bytes) -> "Segment":
        # corruption surfaces as ValueError unconditionally: a bare assert
        # would be stripped under `python -O`, and a truncated buffer would
        # otherwise escape as struct.error
        try:
            return Segment._from_bytes(buf)
        except struct.error as exc:
            raise ValueError(f"corrupt segment: truncated ({exc})") from exc

    @staticmethod
    def _from_bytes(buf: bytes) -> "Segment":
        off = 0
        magic, mcode, n_bytes, n_payload = struct.unpack_from("<IIIi", buf, off)
        off += 16
        if magic != _MAGIC:
            raise ValueError("corrupt segment: bad magic")
        if mcode not in _METHOD_NAMES:
            raise ValueError(f"corrupt segment: unknown method code {mcode}")
        (n_meta,) = struct.unpack_from("<i", buf, off)
        off += 4
        if n_meta < 0 or n_payload < 0:
            raise ValueError("corrupt segment: negative count")
        meta = {}
        for _ in range(n_meta):
            (lk,) = struct.unpack_from("<i", buf, off); off += 4
            if lk < 0:
                raise ValueError("corrupt segment: negative key length")
            k = buf[off:off + lk].decode(); off += lk
            (v,) = struct.unpack_from("<q", buf, off); off += 8
            meta[k] = v
        payload = {}
        for _ in range(n_payload):
            (lk,) = struct.unpack_from("<i", buf, off); off += 4
            if lk < 0:
                raise ValueError("corrupt segment: negative key length")
            k = buf[off:off + lk].decode(); off += lk
            ch, size = struct.unpack_from("<ci", buf, off)
            off += struct.calcsize("<ci")
            try:
                dt = np.dtype(ch.decode())
            except TypeError as exc:
                raise ValueError(
                    f"corrupt segment: bad dtype {ch!r}") from exc
            if size < 0:
                raise ValueError("corrupt segment: negative payload size")
            nb = dt.itemsize * size
            if len(buf) - off < nb:
                raise ValueError("corrupt segment: truncated payload")
            payload[k] = np.frombuffer(buf[off:off + nb], dtype=dt).copy()
            off += nb
        return Segment(_METHOD_NAMES[mcode], n_bytes, payload, meta)


# ------------------------------------------------------------------ codecs --

def huffman_encode(data: np.ndarray, hist: Optional[np.ndarray] = None,
                   codebook: Optional[Tuple[np.ndarray, np.ndarray]] = None) -> Segment:
    data = np.asarray(data, dtype=np.uint8)
    n = data.size
    _check_group_size(n)
    if hist is None:
        hist = np.bincount(data, minlength=256)
    if codebook is None:
        lengths, codes = build_codebook(hist)
    else:
        lengths, codes = codebook
    words, total_bits, chunk_offs = _huffman_pack(
        jnp.asarray(data), jnp.asarray(lengths, dtype=jnp.uint32),
        jnp.asarray(codes))
    n_words = (int(total_bits) + 31) // 32 + 1
    return Segment(
        "huffman", n,
        payload={
            "words": np.asarray(words)[:n_words],
            "chunk_offs": np.asarray(chunk_offs, dtype=np.uint32),
            "lengths": lengths,
        },
        meta={"n_syms": n, "total_bits": int(total_bits)},
    )


def huffman_decode(seg: Segment) -> np.ndarray:
    lengths = seg.payload["lengths"]
    # canonical codes are reconstructible from lengths alone
    codes = _codes_from_lengths(lengths)
    lut_sym, lut_len = _build_decode_lut(lengths, codes)
    n = seg.meta["n_syms"]
    _check_group_size(n)
    if n == 0:
        return np.zeros(0, np.uint8)
    out = _huffman_unpack(jnp.asarray(seg.payload["words"]),
                          jnp.asarray(seg.payload["chunk_offs"]),
                          jnp.asarray(lut_sym), jnp.asarray(lut_len), n)
    return np.asarray(out, dtype=np.uint8)


def _codes_from_lengths(lengths: np.ndarray) -> np.ndarray:
    codes = np.zeros(256, dtype=np.uint32)
    present = np.nonzero(lengths)[0]
    if len(present) == 0:
        return codes
    order = sorted(present, key=lambda s: (lengths[s], s))
    code = 0
    prev_len = lengths[order[0]]
    for s in order:
        code <<= int(lengths[s]) - int(prev_len)
        codes[s] = code
        code += 1
        prev_len = lengths[s]
    return codes


def rle_encode(data: np.ndarray) -> Segment:
    data = np.asarray(data, dtype=np.uint8)
    if data.size == 0:
        return Segment("rle", 0, {"values": np.zeros(0, np.uint8),
                                  "lengths": np.zeros(0, np.uint16)},
                       {"n_syms": 0})
    values, lengths, nruns = _rle_scan(jnp.asarray(data))
    r = int(nruns)
    return Segment("rle", data.size,
                   payload={"values": np.asarray(values[:r]),
                            "lengths": np.asarray(lengths[:r], dtype=np.uint16)},
                   meta={"n_syms": data.size})


def rle_decode(seg: Segment) -> np.ndarray:
    n = seg.meta["n_syms"]
    if n == 0:
        return np.zeros(0, np.uint8)
    out = _rle_expand(jnp.asarray(seg.payload["values"]),
                      jnp.asarray(seg.payload["lengths"].astype(np.int32)), n)
    return np.asarray(out, dtype=np.uint8)


def dc_encode(data: np.ndarray) -> Segment:
    data = np.asarray(data, dtype=np.uint8)
    return Segment("dc", data.size, {"raw": data.copy()}, {"n_syms": data.size})


def dc_decode(seg: Segment) -> np.ndarray:
    return seg.payload["raw"]


# -------------------------------------------------------------- Algorithm 2 --

@dataclasses.dataclass
class HybridConfig:
    group_size: int = 4          # m: bitplanes merged per group
    size_threshold: int = 4096   # T_s bytes
    cr_threshold: float = 1.0    # T_cr
    force: Optional[str] = None  # 'huffman' | 'rle' | 'dc' (benchmark modes)


def compress_group(data: np.ndarray, cfg: HybridConfig = HybridConfig()) -> Segment:
    """Algorithm 2, inner decision for one merged group (byte symbols).

    The paper's CR-threshold decision gains a store-raw fallback: when the
    chosen codec's EXACT serialized size (``exact_stored_bytes``, computable
    from the selection stats before encoding) would not beat storing the
    group raw, fall back to ``dc`` — the estimators' approximate overheads
    can declare a winner that still expands the payload.  ``force`` modes
    skip the fallback (they exist to benchmark a specific codec)."""
    data = np.asarray(data, dtype=np.uint8)
    s = data.size
    _check_group_size(s)
    if cfg.force == "huffman":
        return huffman_encode(data)
    if cfg.force == "rle":
        return rle_encode(data)
    if cfg.force == "dc" or s <= cfg.size_threshold:
        return dc_encode(data)
    hist = np.bincount(data, minlength=256)
    r_h, lengths, codes = estimate_huffman(hist, s)
    if r_h > cfg.cr_threshold:
        bits = int(np.sum(hist * lengths.astype(np.int64)))
        if exact_stored_bytes("huffman", s, total_bits=bits) \
                >= exact_stored_bytes("dc", s):
            return dc_encode(data)
        return huffman_encode(data, hist=hist, codebook=(lengths, codes))
    _, _, nruns = _rle_scan(jnp.asarray(data))
    r_r = estimate_rle(int(nruns), s)
    if r_r > cfg.cr_threshold:
        if exact_stored_bytes("rle", s, n_runs=int(nruns)) \
                >= exact_stored_bytes("dc", s):
            return dc_encode(data)
        return rle_encode(data)
    return dc_encode(data)


def decompress_group(seg: Segment) -> np.ndarray:
    return {"huffman": huffman_decode, "rle": rle_decode, "dc": dc_decode}[seg.method](seg)
