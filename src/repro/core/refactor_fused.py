"""Fused one-dispatch write engine (paper §4-§6 pipeline optimization).

The batched write path of ``refactor.refactor_array`` keeps everything on
device but still drives the encode chain piece by piece: an eager multilevel
decompose (one dispatch per transform op), then per piece one jitted
``align_encode`` plus two jitted ``encode_bitplanes`` calls over ragged
per-level shapes, then one bitcast/slice per merged group — the write path
stays launch-bound, which is exactly the bottleneck the paper's fused
refactoring kernel chain removes (HP-MDR §6, HPDR's fused encode chain).

This module compiles the WHOLE chain — decompose -> exponent alignment /
quantization -> bitplane encode -> per-group byte blobs, plus the scalar
pass (amax / range / per-piece exponents) — into ONE jitted program, cached
per ``(shape, levels, design, mag_bits, group_planes, backend, ...)`` like
``decompose.recompose_plan`` caches the read side:

  * pieces are padded with zeros to whole bitplane tiles (zero elements
    contribute zero bits — bit-identical to the kernels' own padding),
    bucketed by padded word count, and stacked;
  * each bucket's magnitudes and signs encode through one vmapped
    ``kernels.ops.encode_bitplanes_batch`` launch (the write-side twin of
    the read path's ``decode_bitplanes_batch``);
  * the plane stacks are sliced into merged groups and bitcast to stacked
    uint8 blob rows INSIDE the program, so group boundaries cost no extra
    dispatches and ``lossless_batch.encode_groups_stacked`` consumes the
    rows without re-slicing.

Per chunk that is exactly ONE jitted dispatch for the whole encode chain
(``STATS.dispatches``), independent of pieces x groups, and the same three
host syncs as the batched path: one for the fused scalar pass, two inside
the lossless engine.  ``finish_encode`` is separate from ``dispatch_encode``
so the chunked pipeline can keep chunk k+1's fused encode in flight on
device while chunk k's host-side lossless selection, packing, and serialize
run (dispatch-ahead; see ``core.pipeline.ChunkedRefactorPipeline``).

Bit-exactness contract: serializations are byte-identical to the per-piece
paths (``refactor_array(fused=False)`` and ``batched=False``), which stay as
oracles — property-tested in tests/test_refactor_fused.py across shapes,
levels, and designs, including 0-d and empty pieces.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import align as al
from repro.core import decompose as dc
from repro.core import lossless as ll
from repro.core import lossless_batch as lb
from repro.core import refactor as rf
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.obs import trace as obs_trace
from repro import tune as tn


# ------------------------------------------------------------------- stats --

@dataclasses.dataclass
class FusedStats:
    """Counters for the fused write engine (thread-safe, process-global).

    ``dispatches`` counts invocations of the single cached jitted program —
    the write path's dispatch budget is ONE per chunk.  ``plan_builds``
    counts cache misses (trace + compile), so steady-state writes show
    ``plan_builds`` << ``dispatches``."""
    dispatches: int = 0
    finishes: int = 0
    plan_builds: int = 0
    pieces_encoded: int = 0

    def __post_init__(self):
        self._lock = threading.Lock()

    def add(self, **kw: int) -> None:
        with self._lock:
            for k, v in kw.items():
                setattr(self, k, getattr(self, k) + v)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {f.name: getattr(self, f.name)
                    for f in dataclasses.fields(self)}

    def reset(self) -> None:
        with self._lock:
            for f in dataclasses.fields(self):
                setattr(self, f.name, 0)


STATS = FusedStats()


# -------------------------------------------------------------------- plan --

def piece_sizes(shape: Sequence[int], levels: int) -> List[int]:
    """Element count of every decompose piece, statically from the shape.

    Matches ``decompose.decompose`` order: [corner, detail_L (coarsest),
    ..., detail_1]; detail k holds everything of the level-k working shape
    except its coarse corner."""
    shapes = dc.level_shapes(shape, levels)  # [finest ... coarsest]
    prods = [int(np.prod(s, dtype=np.int64)) for s in shapes]
    return [prods[levels]] + [prods[k - 1] - prods[k]
                              for k in range(levels, 0, -1)]


@dataclasses.dataclass(frozen=True)
class _StackEntry:
    """One stacked blob family emitted by the fused program: rows are the
    ``kind`` ('sign' or 'group') blobs of the bucket's pieces."""
    kind: str
    group: int            # group index, -1 for sign
    piece_idxs: Tuple[int, ...]
    n_words: int


@dataclasses.dataclass(frozen=True)
class FusedPlan:
    shape: Tuple[int, ...]
    levels: int
    design: str
    mag_bits: int
    group_planes: Tuple[int, ...]
    piece_ns: Tuple[int, ...]
    entries: Tuple[_StackEntry, ...]
    empty_pieces: Tuple[int, ...]
    run: object           # jitted (x,) -> (exps, amax?, rng?, *blob stacks)
    run_donated: object   # same program, input buffer donated
    has_scalars: bool     # amax/range present (x.size > 0)


def _bytes_rows(planes: jax.Array) -> jax.Array:
    """(B, P, W) uint32 plane stacks -> (B, 4*P*W) uint8 blob rows.

    Row ``b`` is byte-for-byte ``refactor._device_bytes(planes[b])`` — the
    little-endian bitcast layout the per-piece paths serialize."""
    b = planes.shape[0]
    flat = planes.reshape(b, -1)
    return jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(b, -1)


# Cached like decompose.level_merge_fn: the live set is #distinct
# (chunk shape, levels, design) combinations of a workload, far below the
# cap; eviction re-derives the plan rather than pinning compiled programs.
@functools.lru_cache(maxsize=32)
def fused_encode_plan(shape: Tuple[int, ...], levels: int, design: str,
                      mag_bits: int, group_planes: Tuple[int, ...],
                      backend: str, tiles_per_block: int = 8,
                      unroll: str = "butterfly") -> FusedPlan:
    """Build (and cache) the one-dispatch encode program for a chunk shape.

    The returned plan's ``run(x)`` is a single jitted program emitting the
    per-piece exponent vector, the amax/range scalars, and every stacked
    blob family of the chunk (sign planes + merged groups per size bucket).
    """
    STATS.add(plan_builds=1)
    piece_ns = tuple(piece_sizes(shape, levels))
    size = int(np.prod(shape, dtype=np.int64)) if shape else 1
    # bucket non-empty pieces by padded word count: same-padded pieces stack
    # exactly and share one vmapped encode launch
    buckets: Dict[int, List[int]] = {}
    for pi, n in enumerate(piece_ns):
        if n > 0:
            buckets.setdefault(kref.padded_words(n, design), []).append(pi)
    empty_pieces = tuple(pi for pi, n in enumerate(piece_ns) if n == 0)

    entries: List[_StackEntry] = []
    for w, idxs in buckets.items():
        entries.append(_StackEntry("sign", -1, tuple(idxs), w))
        for gi in range(len(group_planes)):
            entries.append(_StackEntry("group", gi, tuple(idxs), w))

    def _run(x):
        x = x.astype(jnp.float32)
        pieces = dc.decompose(x, levels)
        exps = []
        mags: List[jax.Array] = [None] * len(pieces)
        signs: List[jax.Array] = [None] * len(pieces)
        for pi, piece in enumerate(pieces):
            mag, sign, e = al.align_encode(piece, mag_bits)
            exps.append(e)
            mags[pi], signs[pi] = mag, sign
        outs = [jnp.stack(exps)]
        if size:
            outs.append(jnp.max(jnp.abs(x)))
            outs.append(jnp.max(x) - jnp.min(x))
        for w, idxs in buckets.items():
            n_pad = 32 * w
            mstack = jnp.stack([jnp.pad(mags[i], (0, n_pad - piece_ns[i]))
                                for i in idxs])
            sstack = jnp.stack([jnp.pad(signs[i], (0, n_pad - piece_ns[i]))
                                for i in idxs])
            planes = kops.encode_bitplanes_batch(
                mstack, mag_bits, design, backend, tiles_per_block, unroll)
            sign_planes = kops.encode_bitplanes_batch(
                sstack, 1, design, backend, tiles_per_block, unroll)
            outs.append(_bytes_rows(sign_planes))
            row = 0
            for g in group_planes:
                outs.append(_bytes_rows(planes[:, row:row + g, :]))
                row += g
        return tuple(outs)

    # run_donated aliases the input buffer into the program's workspace
    # (donate_argnums) so a pipeline that owns the placed chunk avoids one
    # encode-input allocation per chunk; jit compiles lazily, so the donated
    # twin costs nothing unless a caller opts in (``dispatch_encode(donate=
    # True)`` — gated on backends that implement donation).
    return FusedPlan(shape=tuple(shape), levels=levels, design=design,
                     mag_bits=mag_bits, group_planes=group_planes,
                     piece_ns=piece_ns, entries=tuple(entries),
                     empty_pieces=empty_pieces, run=jax.jit(_run),
                     run_donated=jax.jit(_run, donate_argnums=(0,)),
                     has_scalars=bool(size))


# ---------------------------------------------------------------- dispatch --

@dataclasses.dataclass
class PendingChunk:
    """One chunk's in-flight fused encode: device handles only, no syncs.

    Produced by ``dispatch_encode`` (one jitted dispatch), consumed by
    ``finish_encode`` (scalar sync + lossless engine).  The chunked pipeline
    holds ``dispatch_ahead`` of these so device encode overlaps host
    lossless/serialize work."""
    name: str
    plan: FusedPlan
    hybrid: ll.HybridConfig
    exps: jax.Array                      # (n_pieces,) int32
    amax: Optional[jax.Array]            # None when the chunk is empty
    rng: Optional[jax.Array]
    stacks: Tuple[jax.Array, ...]        # (B, S) uint8 rows, plan.entries order


def donation_supported() -> bool:
    """Whether the current backend implements input-buffer donation (XLA
    ignores donations on CPU with a warning, so the donated program twin is
    only selected on accelerator backends)."""
    return jax.default_backend() in ("gpu", "tpu")


def dispatch_encode(x, name: str = "var",
                    levels: Optional[int] = None,
                    design: Optional[str] = None,
                    mag_bits: Optional[int] = None,
                    hybrid: Optional[ll.HybridConfig] = None,
                    backend: Optional[str] = None,
                    config: Optional[tn.RefactorConfig] = None,
                    donate: bool = False) -> PendingChunk:
    """Launch one chunk's whole encode chain as a single jitted dispatch.

    Returns immediately with device handles; no host synchronization
    happens until ``finish_encode``.  All knobs normalize into ONE
    ``RefactorConfig`` (``config=`` or legacy kwargs — explicit kwargs win;
    see ``repro.tune.config.as_config``), and the fused program is keyed on
    that config's fields, kernel tiling included.

    ``donate=True`` marks ``x`` as dead after the dispatch so XLA may reuse
    its buffer for the encode workspace (no per-chunk input reallocation) —
    pass it ONLY when the caller owns ``x`` exclusively (the chunked
    pipeline's placed copies qualify; caller-held arrays do not).  On
    backends without donation support (CPU) it is a silent no-op and the
    non-donated program runs — output bytes are identical either way."""
    cfg = tn.as_config(config, design=design, mag_bits=mag_bits,
                       hybrid=hybrid, backend=backend)
    hybrid = cfg.hybrid(force=hybrid.force if hybrid is not None else None)
    mag_bits = cfg.resolved_mag_bits()
    x = jnp.asarray(x, dtype=jnp.float32)
    if levels is None:
        levels = dc.num_levels(x.shape)
    group_planes = tuple(rf._group_plane_split(mag_bits, hybrid.group_size))
    with obs_trace.span("encode.dispatch", name=name):
        plan = fused_encode_plan(tuple(x.shape), levels, cfg.design, mag_bits,
                                 group_planes, cfg.backend,
                                 cfg.tiles_per_block, cfg.unroll)
        run = plan.run_donated if donate and donation_supported() \
            else plan.run
        outs = run(x)
        STATS.add(dispatches=1, pieces_encoded=len(plan.piece_ns))
        obs_trace.event(obs_trace.EV_DISPATCH, kind="fused_encode", name=name,
                        pieces=len(plan.piece_ns))
    exps, rest = outs[0], outs[1:]
    amax = rng = None
    if plan.has_scalars:
        amax, rng, rest = rest[0], rest[1], rest[2:]
    return PendingChunk(name=name, plan=plan, hybrid=hybrid, exps=exps,
                        amax=amax, rng=rng, stacks=tuple(rest))


def finish_encode(p: PendingChunk, _scalars=None) -> rf.Refactored:
    """Resolve a dispatched chunk: ONE scalar sync, then the stacked
    lossless engine (two syncs), then host-side manifest assembly.

    ``_scalars`` lets a caller that already gathered the chunk's
    (exps, amax, rng) host values — the sharded round finisher syncs a whole
    round of chunks across devices in one ``host_sync`` — skip the per-chunk
    sync; values must be exactly ``host_sync((p.exps, p.amax, p.rng))``."""
    STATS.add(finishes=1)
    with obs_trace.span("encode.finish", name=p.name):
        scalars = (lb.host_sync((p.exps, p.amax, p.rng),
                                label="encode.scalars")
                   if _scalars is None else _scalars)
        segs_flat = lb.encode_groups_stacked(p.stacks, p.hybrid)
        return _assemble(p, scalars, segs_flat)


def stack_rows(p: PendingChunk) -> int:
    """Total blob rows ``p``'s stacks contribute to a flattened
    ``encode_groups_stacked`` call (the split key of the batched finish)."""
    return sum(int(st.shape[0]) for st in p.stacks)


def finish_encode_many(pendings: Sequence[PendingChunk], _scalars=None
                       ) -> List[rf.Refactored]:
    """Resolve MANY dispatched chunks with batch-amortized host work: ONE
    scalar sync gathers every chunk's (exps, amax, range), and ONE stacked
    lossless pass encodes every chunk's blob rows (two syncs total) — the
    whole batch costs 3 host syncs instead of 3 per chunk.

    Blob rows of all chunks flow through a single ``encode_groups_stacked``
    call (same-size stacks merge ACROSS chunks, so the vmapped pack/scan
    kernels run at batch width = the whole drain window); results come back
    in input order and are byte-identical to ``[finish_encode(p) for p in
    pendings]`` — the batch boundary is a scheduling choice, never a format
    one.  Chunks with differing ``HybridConfig``s are grouped and batched
    per config (the codec decision thresholds are config-dependent)."""
    pendings = list(pendings)
    if not pendings:
        return []
    if _scalars is None:
        _scalars = lb.host_sync([(p.exps, p.amax, p.rng) for p in pendings],
                                label="encode.scalars")
    STATS.add(finishes=len(pendings))
    out: List[Optional[rf.Refactored]] = [None] * len(pendings)
    by_cfg = lb.batch_jobs(pendings, lambda p: (
        p.hybrid.group_size, p.hybrid.size_threshold, p.hybrid.cr_threshold,
        p.hybrid.force))
    with obs_trace.span("encode.finish_many", chunks=len(pendings)):
        for idxs in by_cfg.values():
            segs_flat = lb.encode_groups_stacked(
                [st for i in idxs for st in pendings[i].stacks],
                pendings[idxs[0]].hybrid)
            base = 0
            for i in idxs:
                n = stack_rows(pendings[i])
                out[i] = _assemble(pendings[i], _scalars[i],
                                   segs_flat[base:base + n])
                base += n
    return out


def _assemble(p: PendingChunk, scalars, segs_flat: List[ll.Segment]
              ) -> rf.Refactored:
    """Host-side manifest assembly for one finished chunk: scatter the
    chunk's flattened segment rows back to (piece, kind, group) slots and
    build the ``Refactored``.  ``scalars`` are the synced host values of
    (exps, amax, rng); ``segs_flat`` the chunk's segments in
    ``plan.entries`` row order."""
    plan = p.plan
    with obs_trace.span("encode.assemble", name=p.name):
        exps = [int(e) for e in scalars[0]]
        amax = float(scalars[1]) if p.amax is not None else 0.0
        rng = float(scalars[2]) if p.rng is not None else 0.0

        # scatter flattened rows back to (piece, kind, group) slots
        sign_segs: Dict[int, ll.Segment] = {}
        group_segs: Dict[Tuple[int, int], ll.Segment] = {}
        n_words: Dict[int, int] = {}
        base = 0
        for ent in plan.entries:
            for j, pi in enumerate(ent.piece_idxs):
                seg = segs_flat[base + j]
                if ent.kind == "sign":
                    sign_segs[pi] = seg
                    n_words[pi] = ent.n_words
                else:
                    group_segs[(pi, ent.group)] = seg
            base += len(ent.piece_idxs)
        for pi in plan.empty_pieces:
            # empty pieces reproduce the per-piece encoders exactly: every
            # blob is zero-length, n_words is 0
            sign_segs[pi] = ll.compress_group(np.zeros(0, np.uint8), p.hybrid)
            for gi in range(len(plan.group_planes)):
                group_segs[(pi, gi)] = ll.compress_group(
                    np.zeros(0, np.uint8), p.hybrid)
            n_words[pi] = 0

        ndim = len(plan.shape)
        group_planes = list(plan.group_planes)
        metas: List[rf.PieceMeta] = []
        for pi, n in enumerate(plan.piece_ns):
            groups = [group_segs[(pi, gi)] for gi in range(len(group_planes))]
            for g, seg in zip(group_planes, groups):
                seg.meta["n_planes"] = g
                seg.meta["n_words"] = n_words[pi]
            metas.append(rf.PieceMeta(
                n=n, exponent=exps[pi],
                weight=1.0 if pi == 0 else float((1 << ndim) - 1),
                sign_seg=sign_segs[pi], groups=groups,
                group_planes=group_planes))
        return rf.Refactored(name=p.name, shape=plan.shape,
                             levels=plan.levels, design=plan.design,
                             mag_bits=plan.mag_bits,
                             group_size=p.hybrid.group_size, data_amax=amax,
                             data_range=rng, pieces=metas)


def refactor_fused(x, name: str = "var", levels: Optional[int] = None,
                   design: Optional[str] = None,
                   mag_bits: Optional[int] = None,
                   hybrid: Optional[ll.HybridConfig] = None,
                   backend: Optional[str] = None,
                   config: Optional[tn.RefactorConfig] = None
                   ) -> rf.Refactored:
    """One-call fused refactor: ``finish_encode(dispatch_encode(...))``."""
    return finish_encode(dispatch_encode(
        x, name=name, levels=levels, design=design, mag_bits=mag_bits,
        hybrid=hybrid, backend=backend, config=config))
