"""Batched, device-resident lossless engine (paper §5 on wide batches).

The per-group codecs in ``repro.core.lossless`` are correct but launch one
host-side histogram plus one tiny jit call per (piece, group) and pull every
plane array to host before compressing — O(pieces x groups) host<->device
round-trips per chunk.  This module is the batched formulation the paper's
GPU encoder implies: the whole chunk's merged plane groups stay on device
and flow through a handful of wide kernels.

Write path (``encode_groups``), per call:

  1. stack the chunk's group blobs into same-size buckets (the groups of a
     piece share a size, so a chunk has ~#pieces buckets — stacking is
     exact, no padding work),
  2. one vmapped pass per bucket computes all 256-bin histograms AND all
     RLE run-break counts (``_group_stats_batch``),
  3. **sync #1** (small): every bucket's histograms + run counts come to
     host in one ``device_get``, where Algorithm-2 selection and
     canonical-codebook construction run (the codebook build is a 256-entry
     heap per group — negligible),
  4. the Huffman groups of each bucket are packed by one vmapped
     ``_huffman_pack_batch`` invocation (literally ``vmap`` of the
     reference ``_huffman_pack`` — bit-identity by construction), the RLE
     groups by one ``_rle_scan_batch``,
  5. **sync #2** (payloads): a single ``jax.device_get`` materializes every
     payload of the chunk; host code only trims per-row tails.

That is the one-big-sync-per-chunk contract: exactly two host syncs per
``encode_groups`` call (plus one for the alignment scalars — in
``repro.core.refactor.refactor_array`` on the piece-at-a-time path, in
``repro.core.refactor_fused.finish_encode`` on the default fused path),
and O(#pieces) kernel launches — independent of how many merged groups the
chunk decomposes into.  ``encode_groups_stacked`` is the same engine for
blob rows the fused write program already stacked on device (no re-slice).
Outputs are **bit-identical** to running ``lossless.compress_group`` per
group (tests/test_lossless_batch.py checks serialized bytes).

Read path (``decode_segments``): all same-shape Huffman (resp. RLE)
segments of a request are decoded through one vmapped
``_huffman_unpack``/``_rle_expand`` batch, with a single ``jax.device_get``
for every decoded blob.

All host materialization in this module goes through ``host_sync`` so tests
and benchmarks can count syncs (``STATS``) — and, under an ``obs.tracing``
context, every sync records a typed ``host_sync`` event tagged with its
call-site label on the current span, so traces attribute each sync to the
stage that caused it.

``STATS`` is **context-local** (``obs.trace.ContextLocal``): each
``stats_scope()`` context counts only its own work (dispatch-ahead worker
threads run under a copied context and add to their caller's instance),
while code outside any scope shares the process-global default — the
historical behaviour.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lossless as ll
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


# ------------------------------------------------------------------- stats --

@dataclasses.dataclass
class BatchStats:
    """Counters for the batched engine (thread-safe).

    ``host_syncs`` counts explicit device->host materializations
    (``host_sync`` calls); the refactor write path performs O(1) of them per
    chunk.  ``*_batches`` count kernel-batch invocations, i.e. how many
    launches served how many groups."""
    encode_calls: int = 0
    decode_calls: int = 0
    groups_encoded: int = 0
    groups_decoded: int = 0
    host_syncs: int = 0
    hist_batches: int = 0
    huffman_pack_batches: int = 0
    rle_scan_batches: int = 0
    huffman_unpack_batches: int = 0
    rle_expand_batches: int = 0

    def __post_init__(self):
        self._lock = threading.Lock()

    def add(self, **kw: int) -> None:
        with self._lock:
            for k, v in kw.items():
                setattr(self, k, getattr(self, k) + v)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {f.name: getattr(self, f.name)
                    for f in dataclasses.fields(self)}

    def reset(self) -> None:
        with self._lock:
            for f in dataclasses.fields(self):
                setattr(self, f.name, 0)


class _StatsProxy:
    """Module-level ``STATS`` facade over the context-local instance.

    Preserves the historical ``STATS.add/snapshot/reset`` surface (tests and
    benchmarks keep working unchanged) while routing every access to the
    current context's ``BatchStats`` — the process-global default outside
    any ``stats_scope()``."""

    def __init__(self, ctx: obs_trace.ContextLocal):
        self._ctx = ctx

    def add(self, **kw: int) -> None:
        self._ctx.get().add(**kw)

    def snapshot(self) -> Dict[str, int]:
        return self._ctx.get().snapshot()

    def reset(self) -> None:
        self._ctx.get().reset()

    def __getattr__(self, name: str):
        return getattr(self._ctx.get(), name)


_STATS_CTX = obs_trace.ContextLocal(BatchStats)
STATS = _StatsProxy(_STATS_CTX)


def stats_scope(stats: Optional[BatchStats] = None):
    """Install a fresh (or given) ``BatchStats`` for the current context.

    Worker threads spawned via ``obs.trace.wrap_for_thread`` inside the
    scope share the same instance, so a pipelined write's dispatch-ahead
    syncs land in the caller's scope; concurrent scopes never race on one
    global (regression-tested in tests/test_obs.py)."""
    return _STATS_CTX.scope(stats)


def host_sync(tree, label: str = "host_sync"):
    """The engine's single door to host memory: one counted device_get.

    ``label`` names the call site (``codec.stats``, ``codec.payload``,
    ``codec.decode``, ``encode.scalars``, ...) — under tracing it becomes
    the ``host_sync`` event's attribution key, so benchmarks can report
    syncs-per-chunk broken down by originating span."""
    STATS.add(host_syncs=1)
    obs_trace.event(obs_trace.EV_HOST_SYNC, label=label)
    return jax.device_get(tree)


# ------------------------------------------------------------ device kernels --

@jax.jit
def _group_stats_batch(syms: jax.Array):
    """(B, S) uint8 (a same-size bucket) -> (histograms (B,256) int32,
    RLE run counts (B,) int32), all in one launch.

    On CPU the histogram is sort + searchsorted (XLA CPU serializes
    scatter-adds — ~4x slower than the sort formulation at chunk scale); on
    accelerator backends it is the scatter-add formulation (hardware
    atomics).

    The run-break rule matches ``lossless._rle_scan`` exactly (neighbor
    change or forced break every RLE_BREAK symbols), so the Algorithm-2 RLE
    estimate agrees bit-for-bit with the per-group path."""
    S = syms.shape[1]

    if jax.default_backend() == "cpu":
        edges = jnp.arange(256, dtype=jnp.uint8)

        def hist_one(s):
            bounds = jnp.searchsorted(jnp.sort(s), edges, side="right")
            return jnp.diff(jnp.concatenate(
                [jnp.zeros(1, bounds.dtype), bounds])).astype(jnp.int32)
    else:
        def hist_one(s):
            return jnp.zeros((256,), jnp.int32).at[s.astype(jnp.int32)].add(1)

    hists = jax.vmap(hist_one)(syms)
    idx = jnp.arange(S, dtype=jnp.int32)
    prev = jnp.concatenate([syms[:, :1] ^ jnp.uint8(255), syms[:, :-1]],
                           axis=1)
    brk = (syms != prev) | (idx[None, :] % ll.RLE_BREAK == 0)
    nruns = jnp.sum(brk, axis=1, dtype=jnp.int32)
    return hists, nruns


def _group_stats_host(rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Host twin of ``_group_stats_batch`` for the CPU backend: one
    ``np.bincount`` over offset-shifted symbols computes every row's 256-bin
    histogram, and the run-break rule matches ``lossless._rle_scan`` exactly
    (neighbor change or forced break every RLE_BREAK symbols).

    On the CPU backend the rows already live in host memory, so syncing the
    raw bytes and histogramming here beats the XLA sort-based histogram
    kernel by ~10x at chunk scale — equality with the device kernel is
    pinned in tests/test_lossless_batch.py."""
    B, S = rows.shape
    offs = (np.arange(B, dtype=np.int64) * 256)[:, None]
    hists = np.bincount((rows + offs).reshape(-1), minlength=B * 256)
    hists = hists.reshape(B, 256).astype(np.int32)
    brk = rows[:, 1:] != rows[:, :-1]
    forced = (np.arange(1, S) % ll.RLE_BREAK) == 0
    nruns = 1 + np.sum(brk | forced[None, :], axis=1, dtype=np.int32)
    return hists, nruns


# The batch pack/scan kernels ARE the reference per-group kernels, vmapped
# over a same-size bucket — bit-identity with the per-group encoders holds
# by construction, row for row.

@jax.jit
def _huffman_pack_batch(syms: jax.Array, lens_tab: jax.Array,
                        codes_tab: jax.Array):
    """(B, S) symbols + per-row codebooks -> vmapped ``_huffman_pack``:
    (words (B, cap), total_bits (B,), chunk_offs (B, ceil(S/CHUNK)))."""
    return jax.vmap(ll._huffman_pack)(syms, lens_tab, codes_tab)


@jax.jit
def _rle_scan_batch(syms: jax.Array):
    """(B, S) symbols -> vmapped ``_rle_scan``: per-row (values, lengths,
    nruns); run slots beyond a row's nruns are trimmed on host."""
    return jax.vmap(ll._rle_scan)(syms)


@functools.partial(jax.jit, static_argnames=("n_syms",))
def _huffman_unpack_batch(words: jax.Array, chunk_offs: jax.Array,
                          lut_sym: jax.Array, lut_len: jax.Array,
                          n_syms: int):
    return jax.vmap(lambda w, c, s, l: ll._huffman_unpack(w, c, s, l, n_syms))(
        words, chunk_offs, lut_sym, lut_len)


@functools.partial(jax.jit, static_argnames=("n",))
def _rle_expand_batch(values: jax.Array, lengths: jax.Array, n: int):
    return jax.vmap(lambda v, l: ll._rle_expand(v, l, n))(values, lengths)


# ---------------------------------------------------------------- utilities --

def _pad_stack(blobs: Sequence[jax.Array], length: int) -> jax.Array:
    rows = []
    for b in blobs:
        pad = length - b.shape[0]
        rows.append(jnp.pad(b, (0, pad)) if pad else b)
    return jnp.stack(rows)


def batch_jobs(items, key) -> Dict[tuple, List[int]]:
    """Group item indices by ``key(item)`` — the shared shape-batching
    pattern of this engine and ``repro.store.service.reconstruct_many``."""
    jobs: Dict[tuple, List[int]] = {}
    for i, it in enumerate(items):
        jobs.setdefault(key(it), []).append(i)
    return jobs


# ------------------------------------------------------------------- encode --

def _select(size: int, hist: np.ndarray, n_runs: int, cfg: ll.HybridConfig
            ) -> Tuple[str, Optional[Tuple[np.ndarray, np.ndarray]]]:
    """Algorithm-2 inner decision, host side, from device-computed stats.

    Mirrors ``lossless.compress_group`` decision-for-decision so the batched
    engine picks identical methods (and identical Huffman codebooks)."""
    if cfg.force == "huffman":
        return "huffman", ll.build_codebook(hist)
    if cfg.force == "rle":
        return "rle", None
    if cfg.force == "dc" or size <= cfg.size_threshold:
        return "dc", None
    r_h, lengths, codes = ll.estimate_huffman(hist, size)
    if r_h > cfg.cr_threshold:
        # store-raw fallback (mirrors compress_group): the estimator's
        # approximate overhead can pick a codec that still expands — compare
        # EXACT serialized sizes from the device stats before committing
        bits = int(np.sum(hist * lengths.astype(np.int64)))
        if ll.exact_stored_bytes("huffman", size, total_bits=bits) \
                >= ll.exact_stored_bytes("dc", size):
            return "dc", None
        return "huffman", (lengths, codes)
    if ll.estimate_rle(n_runs, size) > cfg.cr_threshold:
        if ll.exact_stored_bytes("rle", size, n_runs=n_runs) \
                >= ll.exact_stored_bytes("dc", size):
            return "dc", None
        return "rle", None
    return "dc", None


def _host_rows() -> bool:
    """True when every device is a host-memory device (CPU backend): rows
    committed to ANY mesh device are plain host bytes, so the encoder can
    gather them with numpy (zero-copy views, no XLA launch) and merge
    buckets ACROSS devices — one wide kernel batch per group size instead
    of one narrow batch per (size, device).  On accelerators a cross-device
    gather would ship payloads over the link, so there buckets stay
    device-keyed and every kernel runs where its rows live."""
    return jax.default_backend() == "cpu"


def _dev_key(a) -> object:
    """Bucket-key component for the device an array is committed to.

    The batched encoder may see rows from chunks pinned to different mesh
    devices in ONE call (``refactor_fused.finish_encode_many`` drains a
    whole in-flight window); stacking across devices is illegal in jax, so
    — exactly like the read side's ``reconstruct.batch_apply_pending`` —
    encode buckets never mix devices: each device's rows batch separately
    and every kernel runs where its rows live.  Host / uncommitted arrays
    key as ``None``."""
    devs = getattr(a, "devices", None)
    if callable(devs):
        try:
            devs = devs()
        except Exception:  # pragma: no cover - tracer/abstract arrays
            return None
        if devs:
            return tuple(sorted(d.id for d in devs))
    return None


def encode_groups(blobs: Sequence[jax.Array],
                  cfg: ll.HybridConfig = ll.HybridConfig()
                  ) -> List[ll.Segment]:
    """Batched Algorithm 2 over a chunk's merged plane groups.

    ``blobs`` are 1-D uint8 arrays (device-resident; host arrays are
    uploaded).  Returns one ``lossless.Segment`` per blob, bit-identical to
    ``[lossless.compress_group(b, cfg) for b in blobs]``, with exactly two
    host syncs for the whole batch.

    Groups are bucketed by size (the groups of one piece all share a size,
    so a chunk has ~#pieces distinct sizes): every bucket stacks exactly —
    no padding work — and runs through one vmapped stats/pack/scan
    invocation per codec; ALL buckets' stats respectively payloads are
    materialized by the same single ``host_sync``."""
    if not blobs:
        return []
    sizes = [int(np.prod(b.shape, dtype=np.int64)) for b in blobs]
    for s in sizes:
        ll._check_group_size(s)  # before any upload/dispatch
    STATS.add(encode_calls=1, groups_encoded=len(blobs))

    host = _host_rows()
    segs: List[Optional[ll.Segment]] = [None] * len(blobs)
    buckets: Dict[tuple, List[int]] = {}
    for i, s in enumerate(sizes):
        if s == 0:
            # empty groups never touch the device; compress_group reproduces
            # the per-group encoder (incl. force modes) exactly
            segs[i] = ll.compress_group(np.zeros(0, np.uint8), cfg)
        else:
            buckets.setdefault((s, None if host else _dev_key(blobs[i])),
                               []).append(i)
    if not buckets:
        return segs

    if host:
        stacked = {
            k: np.stack([np.asarray(blobs[i], dtype=np.uint8).reshape(-1)
                         for i in idxs])
            for k, idxs in buckets.items()}
    else:
        stacked = {
            k: jnp.stack([jnp.asarray(blobs[i], dtype=jnp.uint8).reshape(-1)
                          for i in idxs])
            for k, idxs in buckets.items()}
    _encode_buckets(stacked, buckets, segs, cfg)
    return segs


def encode_groups_stacked(stacks: Sequence[jax.Array],
                          cfg: ll.HybridConfig = ll.HybridConfig()
                          ) -> List[ll.Segment]:
    """``encode_groups`` for blobs that are ALREADY stacked on device.

    ``stacks`` are (B, S) uint8 device arrays — one group blob per row, as
    emitted by the fused write engine (``core.refactor_fused``): the chunk's
    single jitted program produces each same-size blob family as one stacked
    array, so this entry point never re-slices or re-stacks rows.  Same-size
    stacks are merged (one ``jnp.concatenate`` per size) so the kernel-batch
    count stays O(#distinct sizes), exactly as ``encode_groups``.

    Returns one ``lossless.Segment`` per row, flattened row-major across
    ``stacks`` — bit-identical to calling ``encode_groups`` on the individual
    rows, with the engine's same two host syncs."""
    sizes: List[int] = []
    for st in stacks:
        s = int(st.shape[1])
        ll._check_group_size(s)  # before any dispatch
        sizes.extend([s] * int(st.shape[0]))
    if not sizes:
        return []
    STATS.add(encode_calls=1, groups_encoded=len(sizes))

    host = _host_rows()
    segs: List[Optional[ll.Segment]] = [None] * len(sizes)
    buckets: Dict[tuple, List[int]] = {}
    parts: Dict[tuple, List] = {}
    base = 0
    for st in stacks:
        b, s = int(st.shape[0]), int(st.shape[1])
        if s == 0:
            for i in range(base, base + b):
                segs[i] = ll.compress_group(np.zeros(0, np.uint8), cfg)
        else:
            # host rows (CPU backend): numpy view, merge across devices —
            # a multi-chunk window spanning the whole mesh becomes ONE wide
            # bucket per size, not n_devices narrow ones (see _host_rows)
            k = (s, None if host else _dev_key(st))
            buckets.setdefault(k, []).extend(range(base, base + b))
            parts.setdefault(k, []).append(
                np.asarray(st, np.uint8) if host else jnp.asarray(st,
                                                                  jnp.uint8))
        base += b
    if not buckets:
        return segs

    cat = np.concatenate if host else jnp.concatenate
    stacked = {k: (p[0] if len(p) == 1 else cat(p))
               for k, p in parts.items()}
    _encode_buckets(stacked, buckets, segs, cfg)
    return segs


def _encode_buckets(stacked: Dict[tuple, jax.Array],
                    buckets: Dict[tuple, List[int]],
                    segs: List[Optional[ll.Segment]],
                    cfg: ll.HybridConfig) -> None:
    """Shared stages 1-3 of the batched encoder: stats (sync #1), host-side
    Algorithm-2 selection, vmapped pack/scan (sync #2).  Fills ``segs`` at
    the indices listed in ``buckets``.  Bucket keys are ``(group_size,
    device)`` — a multi-chunk batch spanning mesh devices runs one kernel
    batch per device (rows never move between devices), while both host
    syncs still cover EVERY bucket in one call each.  On the CPU backend
    the device key is always ``None`` (``_host_rows``): every mesh device
    is host memory, so the whole window merges into one wide numpy-stacked
    bucket per size and the pack/scan kernels run once on the default
    device.

    On the CPU backend stage 1 syncs the stacked rows themselves and runs
    the stats host-side (``_group_stats_host``): the XLA CPU histogram
    kernel loses ~10x to ``np.bincount``, dc payloads then come straight
    from the already-synced host rows, and codec row selection becomes an
    ``np.take`` + one upload instead of a device gather per codec.  On
    accelerators stage 1 stays the device kernel — only tiny stats cross
    the PCIe link.  Both paths keep the engine's two-syncs-per-call
    contract and are byte-identical (``hist_batches`` counts stats batch
    computations on either path)."""
    # stage 1: all histograms + run counts, one batch per bucket, ONE sync
    rows_host: Optional[Dict[tuple, np.ndarray]] = None
    if jax.default_backend() == "cpu":
        rows_host = host_sync(stacked, label="codec.stats")
        stats_host = {}
        for k, rows in rows_host.items():
            STATS.add(hist_batches=1)
            stats_host[k] = _group_stats_host(rows)
    else:
        stats_dev = {}
        for k, st in stacked.items():
            STATS.add(hist_batches=1)
            stats_dev[k] = _group_stats_batch(st)
        stats_host = host_sync(stats_dev, label="codec.stats")

    # stage 2: Algorithm-2 selection + codebooks (host, trivial)
    methods: Dict[int, str] = {}
    books: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
    for k, idxs in buckets.items():
        s = k[0]
        hists, nruns = stats_host[k]
        for j, i in enumerate(idxs):
            m, book = _select(s, hists[j].astype(np.int64), int(nruns[j]),
                              cfg)
            methods[i] = m
            if book is not None:
                books[i] = book

    # stage 3: dispatch one pack/scan per (bucket, codec), ONE payload sync
    pend: List[Tuple[str, int, List[int], object]] = []
    for k, idxs in buckets.items():
        s = k[0]
        st = stacked[k]
        pos = {i: j for j, i in enumerate(idxs)}
        h = [i for i in idxs if methods[i] == "huffman"]
        r = [i for i in idxs if methods[i] == "rle"]
        d = [i for i in idxs if methods[i] == "dc"]

        def rows_for(sel_idx: List[int]) -> jax.Array:
            # codec row selection: host take + upload when the rows are
            # already host-side (CPU stats path), device gather otherwise
            if rows_host is not None:
                return jax.device_put(
                    rows_host[k][np.asarray([pos[i] for i in sel_idx])])
            return st[jnp.asarray([pos[i] for i in sel_idx], jnp.int32)]

        if h:
            lens_tab = jax.device_put(
                np.stack([books[i][0] for i in h]).astype(np.uint32))
            codes_tab = jax.device_put(np.stack([books[i][1] for i in h]))
            STATS.add(huffman_pack_batches=1)
            pend.append(("huffman", s, h,
                         _huffman_pack_batch(rows_for(h), lens_tab,
                                             codes_tab)))
        if r:
            STATS.add(rle_scan_batches=1)
            pend.append(("rle", s, r, _rle_scan_batch(rows_for(r))))
        if d:
            if rows_host is not None:
                # dc payloads are the raw rows — already on host, no
                # device round-trip; .copy() detaches from the big stack
                for i in d:
                    segs[i] = ll.Segment("dc", s,
                                         {"raw": rows_host[k][pos[i]].copy()},
                                         {"n_syms": s})
            else:
                pend.append(("dc", s, d, st[jnp.asarray(
                    [pos[i] for i in d], jnp.int32)]))
    mats = host_sync([p[3] for p in pend], label="codec.payload")

    for (kind, s, idxs, _), mat in zip(pend, mats):
        if kind == "huffman":
            words_b, bits_b, offs_b = mat
            for j, i in enumerate(idxs):
                total_bits = int(bits_b[j])
                n_words = (total_bits + 31) // 32 + 1
                segs[i] = ll.Segment(
                    "huffman", s,
                    payload={"words": words_b[j, :n_words].copy(),
                             "chunk_offs": np.array(offs_b[j],
                                                    dtype=np.uint32),
                             "lengths": books[i][0]},
                    meta={"n_syms": s, "total_bits": total_bits})
        elif kind == "rle":
            vals_b, lens_b, nruns_b = mat
            for j, i in enumerate(idxs):
                r = int(nruns_b[j])
                segs[i] = ll.Segment(
                    "rle", s,
                    payload={"values": vals_b[j, :r].copy(),
                             "lengths": lens_b[j, :r].astype(np.uint16)},
                    meta={"n_syms": s})
        else:
            for j, i in enumerate(idxs):
                segs[i] = ll.Segment("dc", s, {"raw": mat[j].copy()},
                                     {"n_syms": s})

    # per-codec byte accounting (obs.metrics): bytes_in is the raw blob
    # size, bytes_out the stored payload — compression_ratio per codec is
    # bytes_in / bytes_out of the same series
    per_codec: Dict[str, List[int]] = {}
    for idxs in buckets.values():
        for i in idxs:
            seg = segs[i]
            acc = per_codec.setdefault(seg.method, [0, 0, 0])
            acc[0] += 1
            acc[1] += seg.n_bytes
            acc[2] += sum(a.nbytes for a in seg.payload.values())
    m = obs_metrics.get()
    for method, (n, bin_, bout) in per_codec.items():
        m.inc("codec.groups", n, codec=method)
        m.inc("codec.bytes_in", bin_, codec=method)
        m.inc("codec.bytes_out", bout, codec=method)


# ------------------------------------------------------------------- decode --

def decode_segments(segs: Sequence[ll.Segment]) -> List[np.ndarray]:
    """Decode many segments, batching same-shape Huffman/RLE decodes.

    Segments sharing (method, n_syms) are decoded through ONE vmapped
    ``_huffman_unpack``/``_rle_expand`` call (Huffman ``words`` are padded to
    the batch max — trailing zeros are exactly what the chunk decoder already
    assumes).  Returns uint8 blobs aligned with ``segs``; bit-identical to
    ``[lossless.decompress_group(s) for s in segs]``."""
    if not segs:
        return []
    STATS.add(decode_calls=1, groups_decoded=len(segs))
    outs: List[Optional[np.ndarray]] = [None] * len(segs)
    pending = []  # (indices, device batch) resolved by one host_sync

    def key(seg: ll.Segment):
        return (seg.method, int(seg.meta.get("n_syms", seg.n_bytes)))

    for (method, n), idxs in batch_jobs(segs, key).items():
        ll._check_group_size(n)  # corrupt metadata must not drive allocation
        if n == 0:
            for i in idxs:
                outs[i] = np.zeros(0, np.uint8)
            continue
        if method == "dc":
            for i in idxs:
                outs[i] = segs[i].payload["raw"]
            continue
        if method == "huffman":
            luts = [ll._build_decode_lut(
                segs[i].payload["lengths"],
                ll._codes_from_lengths(segs[i].payload["lengths"]))
                for i in idxs]
            words = _pad_stack(
                [jnp.asarray(segs[i].payload["words"]) for i in idxs],
                max(segs[i].payload["words"].shape[0] for i in idxs))
            chunk_offs = jnp.stack(
                [jnp.asarray(segs[i].payload["chunk_offs"]) for i in idxs])
            lut_sym = jnp.asarray(np.stack([l[0] for l in luts]))
            lut_len = jnp.asarray(np.stack([l[1] for l in luts]))
            STATS.add(huffman_unpack_batches=1)
            pending.append((idxs, _huffman_unpack_batch(
                words, chunk_offs, lut_sym, lut_len, n)))
        elif method == "rle":
            rmax = max(segs[i].payload["values"].shape[0] for i in idxs)
            values = _pad_stack(
                [jnp.asarray(segs[i].payload["values"]) for i in idxs], rmax)
            lengths = _pad_stack(
                [jnp.asarray(segs[i].payload["lengths"].astype(np.int32))
                 for i in idxs], rmax)
            STATS.add(rle_expand_batches=1)
            pending.append((idxs, _rle_expand_batch(values, lengths, n)))
        else:
            raise ValueError(f"cannot decode method {method!r}")

    if pending:
        mats = host_sync([p[1] for p in pending], label="codec.decode")
        for (idxs, _), mat in zip(pending, mats):
            for j, i in enumerate(idxs):
                outs[i] = np.asarray(mat[j], dtype=np.uint8)
    return outs
