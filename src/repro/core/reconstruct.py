"""Device-resident incremental reconstruction engine (read path).

The transform chain of the read path is linear — bitplane expand -> sign /
scale -> multilevel recompose — so progressive refinement is exactly the
multi-component expansion x~_i = x~_{i-1} + D_i of Duan et al. (progressive
compression framework) and the level-reuse recomposition of HPDR: after the
first reconstruction, a tighter request should cost only a *delta* decode of
the newly fetched plane groups plus a partial recompose, never a from-scratch
rebuild.

``IncrementalReconstructor`` keeps all per-piece reconstruction state on
device:

  * ``mag``   — accumulated uint32 magnitudes.  Newly fetched plane groups
    are decoded *at their bit offsets* (``kernels.ops.decode_bitplanes_offset
    (_batch)``) and OR-ed in; disjoint bit ranges make the accumulation exact,
    so the magnitudes are bit-identical to a full-stack decode.
  * ``sign``  — decoded once, with the piece's first group.
  * ``value`` — the align-decoded float32 coefficients, refreshed only for
    pieces whose magnitudes changed.
  * per-level recompose intermediates — ``reconstruct_device`` re-runs only
    the recompose *suffix* from the coarsest changed piece (HPDR level
    reuse), through the cached per-(shape, levels) plans of
    ``decompose.recompose_plan``.

Bit-exactness contract: the full-decode oracle (``ProgressiveReader(...,
incremental=False)``) and this engine run the *same* jitted per-level merge
programs on bit-identical inputs (integer magnitude accumulation is exact,
``align_decode`` is shared, and a cached level intermediate is bitwise what
the full pass would have computed), so both paths produce bit-identical
reconstructions.  ``tests/test_reconstruct.py`` property-tests this across
shapes, levels, designs, and multi-step tolerance schedules.

Decoding is batchable *across* engines: ``batch_apply_pending`` drains the
staged plane groups of many engines (across pieces, chunks, variables, and
sessions — the store service's serving batch) and decodes every same-shaped
(rows, words, n, offset) bucket through ONE vmapped kernel call.  Nothing in
this module synchronizes with the host: staged rows go up, decoded state and
the reconstruction stay down on device until a caller materializes them.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import align as al
from repro.core import decompose as dc
from repro.core import lossless_batch as lb
from repro.core.refactor import Refactored


# ------------------------------------------------------------------- stats --

@dataclasses.dataclass
class ReconStats:
    """Counters for the incremental read path (thread-safe, process-global).

    ``bytes_decoded`` counts DELTA plane bytes actually run through the
    bitplane decoder; a full-decode path re-decodes every kept plane on every
    reconstruction (compare ``ProgressiveReader.decoded_plane_bytes``).
    ``levels_reused`` counts recompose stages served from the level cache
    instead of being recomputed."""
    groups_staged: int = 0
    rows_decoded: int = 0
    bytes_decoded: int = 0
    delta_decode_batches: int = 0
    sign_decode_batches: int = 0
    recompose_calls: int = 0
    levels_merged: int = 0
    levels_reused: int = 0
    cache_hits: int = 0

    def __post_init__(self):
        self._lock = threading.Lock()

    def add(self, **kw: int) -> None:
        with self._lock:
            for k, v in kw.items():
                setattr(self, k, getattr(self, k) + v)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {f.name: getattr(self, f.name)
                    for f in dataclasses.fields(self)}

    def reset(self) -> None:
        with self._lock:
            for f in dataclasses.fields(self):
                setattr(self, f.name, 0)


STATS = ReconStats()


@jax.jit
def _or_u32(a: jax.Array, b: jax.Array) -> jax.Array:
    return a | b


@dataclasses.dataclass
class _PendingRows:
    """Staged, not-yet-decoded plane rows of one piece (device-resident)."""
    piece: int
    rows: jax.Array        # (P', W) uint32, MSB-first slice
    row_offset: int        # rows already decoded into the piece's magnitudes


class IncrementalReconstructor:
    """Per-variable(-chunk) device-resident incremental reconstruction state.

    Fed by a ``ProgressiveReader``: ``stage_rows``/``stage_sign`` upload newly
    fetched plane groups, ``reconstruct_device`` returns the up-to-date
    reconstruction as a device array.  Decode work staged here may instead be
    drained by ``batch_apply_pending`` to share kernel launches across many
    engines (the store service's cross-session batch)."""

    def __init__(self, ref: Refactored, backend: str = "auto",
                 device: Optional[jax.Device] = None,
                 config=None):
        from repro import tune as tn  # local: keep import graph flat
        self.ref = ref
        self.backend = backend
        # replayed plan knobs (store manifest / tuned config): decode kernel
        # tiling — part of every batch bucket key so one drained batch never
        # mixes kernel variants
        cfg = config if config is not None else tn.DEFAULT_CONFIG
        self.tiles_per_block = cfg.tiles_per_block
        self.unroll = cfg.unroll
        # owning device of this engine's state (mesh-sharded read path:
        # core.sharded places each chunk's engine on the chunk's device).
        # None = today's single-device path: uncommitted default-device
        # arrays, bit-identical placement-free behavior.
        self.device = device
        # delta plane bytes decoded into THIS engine — per-instance so
        # callers (the QoI loop's per-iteration accounting) stay correct
        # under concurrent sessions; STATS is the process-global aggregate
        self.bytes_decoded = 0
        n_pieces = len(ref.pieces)
        self._mag: List[Optional[jax.Array]] = [None] * n_pieces
        self._sign: List[Optional[jax.Array]] = [None] * n_pieces
        self._value: List[Optional[jax.Array]] = [None] * n_pieces
        self._kept: List[int] = [0] * n_pieces     # planes decoded into _mag
        self._dirty: set = set()
        self._pending: List[_PendingRows] = []
        self._pending_sign: List[Tuple[int, jax.Array]] = []
        # serving-tier mode (repro.store.serving): staged work is a list of
        # (kind, piece, future) whose decoded plane groups arrive from the
        # SHARED cross-session decoder instead of this engine's private
        # kernel batch.  ``shared`` is the owning ServingTier (duck-typed —
        # core never imports store); drained via ``shared.drain_engines``.
        self.shared = None
        self._shared_pending: List[Tuple[str, int, object]] = []
        # recompose level cache: _levels[0] = reshaped corner, _levels[i] =
        # state after merging detail piece i; x_hat = _levels[levels]
        self._levels: Optional[List[jax.Array]] = None

    # ------------------------------------------------------------- staging --
    def _upload(self, rows) -> jax.Array:
        """Host rows -> this engine's device (uncommitted when device=None)."""
        if self.device is None:
            return jnp.asarray(rows, jnp.uint32)
        if isinstance(rows, jax.Array):
            return jax.device_put(rows.astype(jnp.uint32), self.device)
        return jax.device_put(np.asarray(rows, np.uint32), self.device)

    def stage_sign(self, piece: int, rows) -> None:
        """(1, W) uint32 sign plane of a piece's first fetch."""
        if self.ref.pieces[piece].n == 0:
            return
        self._pending_sign.append((piece, self._upload(rows)))

    def stage_rows(self, piece: int, rows, row_offset: int) -> None:
        """(P', W) uint32 plane rows sitting ``row_offset`` rows into the
        piece's MSB-first stack.  Upload only; decode happens batched."""
        if self.ref.pieces[piece].n == 0 or rows.shape[0] == 0:
            return
        self._pending.append(_PendingRows(
            piece, self._upload(rows), row_offset))
        STATS.add(groups_staged=1)

    def stage_shared(self, kind: str, piece: int, fut) -> None:
        """Register a serving-tier decode future (``kind`` is "sign" or
        "group").  The decoded planes are produced (or cache-served) by the
        shared tier and OR-applied at drain time — same exactness argument
        as private staging: magnitude accumulation over disjoint bit ranges
        commutes, so apply order across sessions does not matter."""
        if self.ref.pieces[piece].n == 0:
            return
        self._shared_pending.append((kind, piece, fut))
        STATS.add(groups_staged=1)

    def _take_pending(self) -> List[_PendingRows]:
        out, self._pending = self._pending, []
        return out

    def _take_pending_sign(self) -> List[Tuple[int, jax.Array]]:
        out, self._pending_sign = self._pending_sign, []
        return out

    def _apply_mag(self, piece: int, mag_delta: jax.Array, n_rows: int) -> None:
        cur = self._mag[piece]
        self._mag[piece] = (mag_delta if cur is None
                            else _or_u32(cur, mag_delta))
        self._kept[piece] += n_rows
        self._dirty.add(piece)

    def _apply_sign(self, piece: int, sign: jax.Array) -> None:
        self._sign[piece] = sign
        self._dirty.add(piece)

    # -------------------------------------------------------- reconstruction --
    def _piece_value(self, pi: int) -> jax.Array:
        v = self._value[pi]
        if v is None:
            v = jnp.zeros((self.ref.pieces[pi].n,), jnp.float32)
            if self.device is not None:
                v = jax.device_put(v, self.device)
            self._value[pi] = v
        return v

    def reconstruct_device(self) -> jax.Array:
        """Current reconstruction as a device array (shape ``ref.shape``).

        Decodes any still-pending plane groups (batched), align-decodes only
        the changed pieces, and re-runs only the recompose suffix below the
        coarsest changed piece; a clean engine returns the cached array."""
        if self._pending or self._pending_sign or self._shared_pending:
            batch_apply_pending([self])
        r = self.ref
        if not self._dirty and self._levels is not None:
            STATS.add(cache_hits=1)
            return self._levels[r.levels]
        for pi in self._dirty:
            pm = r.pieces[pi]
            if self._kept[pi] == 0 or pm.n == 0:
                continue
            self._value[pi] = al.align_decode(
                self._mag[pi], self._sign[pi], jnp.int32(pm.exponent),
                r.mag_bits, planes_kept=self._kept[pi])
        plan = dc.recompose_plan(r.shape, r.levels)
        if self._levels is None or 0 in self._dirty:
            shapes = dc.level_shapes(r.shape, r.levels)
            self._levels = [self._piece_value(0).reshape(shapes[-1])
                            ] + [None] * r.levels
            start = 1
        else:
            start = min(self._dirty)
        for i in range(start, r.levels + 1):
            _, merge = plan[i - 1]
            self._levels[i] = merge(self._levels[i - 1], self._piece_value(i))
        STATS.add(recompose_calls=1, levels_merged=r.levels - start + 1,
                  levels_reused=start - 1)
        self._dirty.clear()
        return self._levels[r.levels]


# ------------------------------------------------- cross-engine batched decode

def batch_apply_pending(engines: Sequence[IncrementalReconstructor]) -> None:
    """Drain and decode the staged plane groups of many engines.

    All staged (rows, words, n, row_offset)-compatible groups — across
    pieces, engines, chunks, variables, and sessions — decode through ONE
    vmapped ``decode_bitplanes_offset_batch`` launch per bucket (grouping via
    ``lossless_batch.batch_jobs``, the engine-shared pattern); sign planes
    batch the same way.  Decoded magnitudes are OR-accumulated into each
    engine's device state; no host sync happens here."""
    from repro.kernels import ops as kops  # local: keeps import graph flat

    # serving-tier engines first: their staged futures resolve through the
    # SHARED cross-session decoder (one combined, fairness-bounded batch per
    # tier), then each result is OR-applied into its engine.  Grouped by
    # tier so one drain merges every engine's futures into one pump.
    tiers: Dict[int, Tuple[object, List[IncrementalReconstructor]]] = {}
    for e in engines:
        if e._shared_pending and e.shared is not None:
            tiers.setdefault(id(e.shared), (e.shared, []))[1].append(e)
    for tier, tier_engines in tiers.values():
        tier.drain_engines(tier_engines)

    jobs: List[Tuple[IncrementalReconstructor, _PendingRows]] = [
        (e, p) for e in engines for p in e._take_pending()]
    sign_jobs: List[Tuple[IncrementalReconstructor, int, jax.Array]] = [
        (e, pi, rows) for e in engines
        for pi, rows in e._take_pending_sign()]

    def key(job):
        e, p = job
        # the engine's owning device is part of the bucket: sharded engines
        # (core.sharded) never mix devices in one stacked decode, so each
        # kernel launch runs where its engine state lives
        return (int(p.rows.shape[0]), int(p.rows.shape[1]), p.row_offset,
                e.ref.pieces[p.piece].n, e.ref.mag_bits, e.ref.design,
                e.backend, e.tiles_per_block, e.unroll, e.device)

    for k, pos in lb.batch_jobs(jobs, key).items():
        n_rows, _, offset, n, mag_bits, design, backend, tiles, unroll, _dev = k
        batch = [jobs[p] for p in pos]
        stacked = jnp.stack([p.rows for _, p in batch])
        mags = kops.decode_bitplanes_offset_batch(
            stacked, mag_bits, n, offset, design, backend=backend,
            tiles_per_block=tiles, unroll=unroll)
        row_bytes = 4 * n_rows * int(stacked.shape[2])
        STATS.add(delta_decode_batches=1, rows_decoded=n_rows * len(batch),
                  bytes_decoded=row_bytes * len(batch))
        for j, (e, p) in enumerate(batch):
            e.bytes_decoded += row_bytes
            e._apply_mag(p.piece, mags[j], n_rows)

    def sign_key(job):
        e, pi, rows = job
        return (int(rows.shape[1]), e.ref.pieces[pi].n, e.ref.design,
                e.backend, e.tiles_per_block, e.unroll, e.device)

    for k, pos in lb.batch_jobs(sign_jobs, sign_key).items():
        _, n, design, backend, tiles, unroll, _dev = k
        batch = [sign_jobs[p] for p in pos]
        stacked = jnp.stack([rows for _, _, rows in batch])
        sgs = kops.decode_bitplanes_batch(stacked, 1, n, design,
                                          backend=backend,
                                          tiles_per_block=tiles,
                                          unroll=unroll)
        # sign planes count toward the delta bytes: the full-decode baseline
        # (ProgressiveReader.decoded_plane_bytes) includes them too
        row_bytes = 4 * int(stacked.shape[2])
        STATS.add(sign_decode_batches=1, rows_decoded=len(batch),
                  bytes_decoded=row_bytes * len(batch))
        for j, (e, pi, _) in enumerate(batch):
            e.bytes_decoded += row_bytes
            e._apply_sign(pi, sgs[j])
