"""Exponent alignment: float <-> sign-magnitude fixed point.

HP-MDR (Alg. 1, step 1) aligns all values of a (level-)array to the global
maximum exponent so bitplane boundaries are consistent across elements.

fp32 path: Bm = 23 magnitude bits in an int32 word (sign kept separately).
With ``e = frexp_exponent(max|x|)`` (i.e. ``max|x| = m * 2**e, m in [0.5,1)``)
and ``scale = 2**(Bm - e)`` we have ``|round(x*scale)| < 2**Bm`` for all x,
so the magnitude always fits in Bm bits.  Bm=23 keeps ``x*scale`` <= 2**23,
where float32 represents every integer EXACTLY — with a larger Bm the
product itself rounds (fp32 ulp > 1 above 2**24) and the 0.5-ulp
quantization bound would be violated.  23 bits is also precisely the
information content of fp32 at the aligned exponent, so nothing is lost:
this matches the paper's alignment to the global maximum exponent.

Error model (used by the retrieval planner, verified by property tests):
  keeping the top ``P`` of ``Bm`` planes, with midpoint reconstruction of the
  truncated tail, gives
      |x - decode(P)| <= (2**(Bm-P-1) + 0.5) / scale      for 0 < P < Bm
      |x - decode(Bm)| <= 0.5 / scale                     (near-lossless floor)
      |x - 0|         <= 2**e                             for P = 0
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_MAG_BITS = 23  # fp32 path: largest Bm with exact fp32 quantization


def max_exponent(x: jax.Array) -> jax.Array:
    """Return integer e with max|x| <= 2**e (frexp convention), e=0 if x==0."""
    if x.size == 0:
        return jnp.zeros((), jnp.int32)
    amax = jnp.max(jnp.abs(x))
    # frexp: amax = m * 2**e with m in [0.5, 1)
    _, e = jnp.frexp(amax)
    return jnp.where(amax > 0, e, jnp.zeros_like(e)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("mag_bits",))
def align_encode(
    x: jax.Array, mag_bits: int = DEFAULT_MAG_BITS
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize to sign-magnitude fixed point aligned at the max exponent.

    Returns (magnitude uint32 [same shape], sign uint32 0/1, exponent int32 scalar).
    """
    x = x.astype(jnp.float32)
    e = max_exponent(x)
    scale = jnp.exp2((mag_bits - e).astype(jnp.float32))
    q = jnp.round(x * scale)
    sign = (q < 0).astype(jnp.uint32)
    mag = jnp.abs(q).astype(jnp.uint32)
    return mag, sign, e


@functools.partial(jax.jit, static_argnames=("mag_bits", "planes_kept"))
def align_decode(
    mag: jax.Array,
    sign: jax.Array,
    e: jax.Array,
    mag_bits: int = DEFAULT_MAG_BITS,
    planes_kept: int | None = None,
) -> jax.Array:
    """Inverse of align_encode. If ``planes_kept`` < mag_bits, the magnitude is
    assumed already truncated to its top ``planes_kept`` planes and a midpoint
    correction of the truncated tail is applied (MDR-style unbiased decode)."""
    p = mag_bits if planes_kept is None else planes_kept
    mag = mag.astype(jnp.uint32)
    if p < mag_bits:
        tail = mag_bits - p
        mag = (mag >> tail) << tail
        # midpoint of the truncation interval; applied even at mag==0 (the
        # sign plane travels with the first group, so sign is known).
        mag = mag + jnp.uint32(1 << (tail - 1)) if tail >= 1 else mag
    scale = jnp.exp2((mag_bits - e).astype(jnp.float32))
    val = mag.astype(jnp.float32) / scale
    return jnp.where(sign > 0, -val, val)


def truncation_error(e: int | np.ndarray, planes_kept: int, mag_bits: int = DEFAULT_MAG_BITS) -> float:
    """Conservative max-norm error bound for keeping ``planes_kept`` planes."""
    e = np.asarray(e, dtype=np.float64)
    if planes_kept <= 0:
        return float(np.exp2(e))
    scale = np.exp2(mag_bits - e)
    if planes_kept >= mag_bits:
        return float(0.5 / scale)
    return float((np.exp2(mag_bits - planes_kept - 1) + 0.5) / scale)
