"""AdamW with dtype-configurable moment states (bf16 moments for the >=100B
configs keep the 671B memory plan under 16 GB/chip), global-norm clipping and
a linear-warmup/cosine schedule.  States share the parameter PartitionSpecs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    state_dtype: str = "float32"


def init(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def state_partition_specs(param_specs) -> AdamWState:
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(), m=param_specs, v=param_specs)


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(grads, state: AdamWState, params, cfg: AdamWConfig
           ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, step.astype(jnp.float32))
    sdt = jnp.dtype(cfg.state_dtype)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g) * (1 - b2)
        mhat = m32 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(sdt), v32.astype(sdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in outs])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
